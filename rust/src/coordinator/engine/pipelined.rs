//! Pipelined multi-worker engine shell: a pool of worker threads drives
//! one in-flight decode batch each against a SHARED scheduler/KV wall,
//! with slot prefills either performed by the joining worker (`prefill =
//! sync`, the default) or issued to a dedicated prefill-executor THREAD
//! (`prefill = async`) so recycling overlaps decode for real. On top of
//! the shared decode core it adds two scheduling features the monolith
//! blocked:
//!
//! * **Cross-worker work stealing** (`steal = on`, default): a drained
//!   lane adopts queued tasks from the shared queue *and*, when the queue
//!   cannot feed it, steals a not-yet-joined refill from the most-loaded
//!   peer instead of parking on the condvar — the Sparrow late-binding
//!   move. Stolen refills are safe by construction: their KV admission is
//!   already charged globally, the slot write only happens at join time
//!   on whichever lane owns the refill then (and an async-prepared result
//!   is keyed by task, not lane), and per-task RNG keeps the tokens
//!   identical wherever the task lands. A peer is only robbed while it
//!   has ≥ 2 pending refills (or ≥ 1 while it still decodes a live
//!   batch), so a lone about-to-join refill can never ping-pong between
//!   two drained lanes.
//! * **Makespan-aware admission order**: the shared queue is an
//!   [`AdmissionQueue`] (fifo, or shortest-predicted-residency-first via
//!   a sorted index with the stable first-min tie-break) — see
//!   `scheduler.rs`.
//!
//! **Prefill modes and the virtual clock.** The modeled hardware is
//! disaggregated serving. Under `async`, slot prefills run on the single
//! shared prefill lane (`lane_clock`) — and, matching the model, a real
//! executor thread makes the backend `prepare_prefill` calls off the
//! decode workers, delivering completions through `PipeShared`; the
//! worker's `apply_prefill` at join time is the cheap slot write. Under
//! `sync` (the original behavior) the joining worker makes the backend
//! call itself, so the virtual clock honestly charges
//! `slot_prefill_ticks` to that worker's decode lane — the blocking cost
//! `bench_rollout`'s sync-vs-async scenario holds strictly above the
//! async makespan. Tokens are identical in both modes (per-task RNG);
//! only the timing model and the threading differ.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::data::task::Task;

use super::super::backend::RolloutBackend;
use super::super::kv_manager::KvMemoryManager;
use super::super::scheduler::{AdmissionQueue, Scheduler};
use super::core::{
    self, admission_costs, admit_next, prefill_chunk_step, prefill_single_row, ChunkInProgress,
    DecodeCore, GenSeq, Geometry, PrefillCache, PrefillWave, StreamHub,
};
use super::stats::RolloutStats;
use super::{RolloutCtx, RolloutPolicy};

/// A slot refill admitted to the wall but not yet joined into a worker's
/// decode batch. Its KV reservation is already held; the owning lane
/// joins it (or a drained peer steals it) once that lane's virtual clock
/// reaches `ready_at`.
struct PendingRefill {
    /// Position in the pending task list (== results index).
    pos: usize,
    /// Virtual time the refill becomes joinable: the shared prefill
    /// lane's completion (async), or the issue time (sync — the joining
    /// worker pays the call itself at join).
    ready_at: u64,
}

/// State the pipelined worker threads (and the async prefill executor)
/// coordinate on, behind one mutex: the shared task queue, the shared
/// scheduler + KV wall, the result table, the per-lane pending-refill
/// registries (the steal surface), the executor's request/completion
/// hand-off, and the virtual clocks that tie the lanes' timelines
/// together. `P` is the backend's prepared-prefill payload.
struct PipeShared<'s, P> {
    queue: AdmissionQueue,
    sched: &'s mut Scheduler,
    kv: &'s mut KvMemoryManager,
    results: Vec<Option<GenSeq>>,
    /// Admitted-but-not-yet-joined refills, one registry per lane, each
    /// ascending in `ready_at`. A lane pops its own front to join;
    /// `steal` lets a drained lane pop a loaded peer's back instead of
    /// parking.
    refills: Vec<VecDeque<PendingRefill>>,
    /// Live decode-batch occupancy per lane (steal victim selection: a
    /// lane that still decodes will not join its refills for a while).
    lane_live: Vec<usize>,
    /// Virtual clock of the single shared prefill lane (async mode).
    lane_clock: u64,
    /// Latest virtual time any lane released KV — the earliest honest
    /// timestamp for an admission that had to wait on the wall.
    release_floor: u64,
    /// Sequences currently admitted across all lanes (live + pending).
    live_now: usize,
    /// Peak of `live_now`: the globally admitted width.
    peak_live: usize,
    /// Async executor hand-off: submitted task positions awaiting
    /// preparation, and prepared payloads awaiting their join (keyed by
    /// task position so stolen refills find theirs).
    prefill_queue: VecDeque<usize>,
    prepared: BTreeMap<usize, P>,
    /// Executor counters (all 0 in sync mode). `joined` is the in-flight
    /// denominator: peak in-flight = max over submits of
    /// `submitted - joined`, which advances on virtual-clock events only
    /// and is therefore deterministic at one worker.
    prefill_submitted: usize,
    prefill_completed: usize,
    prefill_joined: usize,
    prefill_inflight_peak: usize,
    /// Executor-side bounded retries (async mode; merged into the final
    /// stats' `retries` — the workers count their own inline).
    exec_retries: usize,
    /// Task positions whose async `prepare_prefill` exhausted its retry
    /// budget under `fault-policy = quarantine`: the joining worker
    /// consumes the marker and quarantines the task instead of waiting
    /// forever for a payload that will never arrive.
    failed_prepares: BTreeSet<usize>,
    /// Live token sink (serving front-ends); each worker lane clones it
    /// into its own `DecodeCore`. `None` keeps streaming a strict no-op.
    stream: Option<StreamHub>,
    /// Workers that finished their drain (the executor's shutdown gate).
    workers_done: usize,
    workers_total: usize,
    /// First worker/executor error, if any — parked peers bail instead
    /// of waiting for releases that will never come.
    failed: Option<String>,
}

impl<P> PipeShared<'_, P> {
    /// Admit the scheduler's next queue pick: wall charge + global width
    /// accounting, in one place so the admission sites (initial wave,
    /// slot refills, parked retry) cannot drift. `None` means the queue
    /// is empty or the wall refused.
    fn admit_next(&mut self, tasks: &[(usize, &Task)], seq_id_base: u64) -> Option<usize> {
        let pos = admit_next(self.sched, self.kv, &mut self.queue, tasks, seq_id_base)?;
        self.live_now += 1;
        self.peak_live = self.peak_live.max(self.live_now);
        Some(pos)
    }

    /// Issue one prefill on the shared lane, starting no earlier than the
    /// caller's local time `now`; returns its completion time.
    fn lane_issue(&mut self, now: u64, ticks: u64) -> u64 {
        self.lane_clock = self.lane_clock.max(now) + ticks;
        self.lane_clock
    }

    /// Register one admitted refill for lane `me` at local time `now`:
    /// compute its virtual ready time (async: the shared prefill lane;
    /// sync: immediately — the worker pays the device call at join) and,
    /// in async mode, hand the prompt to the executor. Callers notify the
    /// condvar after dropping the lock when `asynch`.
    fn issue_refill(&mut self, me: usize, pos: usize, now: u64, ticks: u64, asynch: bool) {
        let ready_at = if asynch { self.lane_issue(now, ticks) } else { now };
        self.refills[me].push_back(PendingRefill { pos, ready_at });
        if asynch {
            self.prefill_queue.push_back(pos);
            self.prefill_submitted += 1;
            let inflight = self.prefill_submitted - self.prefill_joined;
            self.prefill_inflight_peak = self.prefill_inflight_peak.max(inflight);
        }
    }

    /// Account a release/preemption happening at the caller's local time
    /// `now` — the floor a peer's stalled admission jumps its clock to.
    fn release_at(&mut self, now: u64) {
        self.live_now -= 1;
        self.release_floor = self.release_floor.max(now);
    }

    /// Record the wall's current residency into a lane's stats (exact
    /// global peaks: every admission/grow site snapshots under the mutex).
    fn snap_residency(&self, stats: &mut RolloutStats) {
        core::snap_residency(self.kv, stats);
    }

    /// Steal one pending refill for drained lane `me`: rob the back of
    /// the most-loaded peer registry (latest `ready_at` — the entry its
    /// owner would reach last). A peer qualifies with ≥ 2 pending
    /// refills, or ≥ 1 while its decode batch is still live — so a lone
    /// refill on an otherwise-drained peer stays put (it is that lane's
    /// only way forward, and robbing it back and forth could livelock
    /// two idle lanes).
    fn steal_for(&mut self, me: usize) -> Option<PendingRefill> {
        let victim = (0..self.refills.len())
            .filter(|&w| {
                w != me
                    && (self.refills[w].len() >= 2
                        || (self.refills[w].len() == 1 && self.lane_live[w] > 0))
            })
            .max_by_key(|&w| self.refills[w].len())?;
        self.refills[victim].pop_back()
    }
}

/// Poisons the run if a pipelined thread UNWINDS: the normal error
/// wrapper only sees returned `Err`s, but a panic (e.g. a violated
/// `expect` invariant outside the lock, which leaves the mutex
/// unpoisoned) would otherwise strand parked peers — and the async
/// executor's shutdown gate (`workers_done`) — waiting forever. Disarm
/// after a normal return; on drop-while-armed, set `failed` and wake
/// everyone.
struct PanicFence<'m, 's, P> {
    shared: &'m Mutex<PipeShared<'s, P>>,
    cv: &'m Condvar,
    disarmed: bool,
}

impl<P> Drop for PanicFence<'_, '_, P> {
    fn drop(&mut self) {
        if self.disarmed {
            return;
        }
        if let Ok(mut sh) = self.shared.lock() {
            if sh.failed.is_none() {
                sh.failed = Some("a pipelined thread panicked".into());
            }
        }
        // (a panic while holding the lock poisons the mutex instead;
        // peers' lock() calls already bail on that)
        self.cv.notify_all();
    }
}

/// The dedicated async prefill executor: drains submitted requests off
/// the shared queue, runs the expensive cache-independent
/// `prepare_prefill` on ITS OWN backend — concurrently with every decode
/// worker — and delivers the payloads back through `PipeShared`. Exits
/// when all workers have drained (or any thread failed). This thread is
/// what turns the modeled prefill lane into real overlap on the artifact
/// path.
fn prefill_executor<B: RolloutBackend>(
    b: &mut B,
    tasks: &[(usize, &Task)],
    retries: usize,
    quarantine: bool,
    shared: &Mutex<PipeShared<'_, B::Prepared>>,
    cv: &Condvar,
) -> Result<()> {
    let lock = || {
        shared
            .lock()
            .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))
    };
    loop {
        let pos = {
            let mut guard = lock()?;
            loop {
                if guard.failed.is_some() {
                    return Ok(()); // a peer already poisoned the run
                }
                if let Some(pos) = guard.prefill_queue.pop_front() {
                    break pos;
                }
                if guard.workers_done == guard.workers_total {
                    return Ok(()); // drained: no more submissions can come
                }
                let (g, _) = cv
                    .wait_timeout(guard, Duration::from_millis(2))
                    .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))?;
                guard = g;
            }
        };
        // the expensive half runs OFF the lock and OFF the decode workers;
        // its modeled latency was charged to the shared prefill lane at
        // issue time, so executor retries only count — they add no ticks
        let mut attempt = 0usize;
        let prepared = loop {
            match b.prepare_prefill(&tasks[pos].1.prompt_ids) {
                Ok(p) => break Some(p),
                Err(e) if attempt < retries => {
                    attempt += 1;
                    lock()?.exec_retries += 1;
                    let _ = e;
                }
                Err(e) if quarantine => {
                    // deliver a failure marker instead of a payload: the
                    // joining worker quarantines the task (abort policy
                    // instead fails the run, below)
                    let _ = e;
                    break None;
                }
                Err(e) => return Err(e),
            }
        };
        let mut guard = lock()?;
        match prepared {
            Some(p) => {
                guard.prefill_completed += 1;
                guard.prepared.insert(pos, p);
            }
            None => {
                guard.failed_prepares.insert(pos);
            }
        }
        drop(guard);
        cv.notify_all();
    }
}

impl RolloutPolicy {
    /// Pipelined rollout: `backends.len()` worker threads, each driving a
    /// continuous-style decode batch over its own backend against the
    /// shared scheduler/KV wall; slot prefills are performed by the
    /// joining worker (`prefill = sync`) or prepared by the dedicated
    /// executor thread on `prefill_backend` (`prefill = async` — the
    /// executor backend is required then and ignored otherwise); drained
    /// lanes adopt queued work and (with `steal`) rob loaded peers
    /// instead of parking.
    ///
    /// Token identity with `continuous` holds by construction: per-task
    /// RNG plus batch-row independence make a task's tokens a pure
    /// function of (seed, task) regardless of worker, slot, join step,
    /// steal, admission order, prefill mode, or preemption —
    /// `tests/engine_equivalence.rs` enforces it for worker counts 1/2/4
    /// across the {steal} × {admission-order} × {sync, async} grid.
    /// Results come back in task order. Work counters in the merged stats
    /// sum over lanes; `modeled_makespan_ticks` is the lane max,
    /// `peak_live_slots` the peak globally admitted width, and the
    /// `async_prefills_*` counters the executor's global totals.
    pub fn rollout_pipelined<B: RolloutBackend + Send>(
        &self,
        backends: &mut [B],
        prefill_backend: Option<&mut B>,
        tasks: &[(usize, &Task)],
        seed: u64,
        ctx: RolloutCtx,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let RolloutCtx { sched, kv, seq_id_base, stream } = ctx;
        let workers = backends.len();
        if workers == 0 {
            bail!("pipelined rollout needs at least one worker backend");
        }
        let asynch = self.prefill.is_async();
        if asynch && prefill_backend.is_none() {
            bail!("prefill = async needs a dedicated prefill-executor backend");
        }
        let prefill_backend = if asynch { prefill_backend } else { None };
        let n = tasks.len();
        if n == 0 {
            return Ok((vec![], RolloutStats { workers, ..RolloutStats::default() }));
        }
        // every worker (and the executor) must see the same model
        // geometry — they share one task queue and one wall
        let shape = Geometry::of(&backends[0]).shape();
        for b in backends.iter() {
            let g = Geometry::of(b).shape();
            if g != shape {
                bail!("pipelined worker backends disagree on geometry: {g:?} vs {shape:?}");
            }
        }
        if let Some(eb) = prefill_backend.as_deref() {
            let g = Geometry::of(eb).shape();
            if g != shape {
                bail!("prefill-executor backend disagrees on geometry: {g:?} vs {shape:?}");
            }
        }
        // same progress guarantee as the continuous engine: a lone
        // sequence must be able to grow to its worst-case residency
        if kv.pages_for(sched.reserve_per_seq) > kv.total_pages() {
            bail!(
                "pipelined rollout deadlock: one sequence may need {} KV tokens \
                 but the wall holds only {}",
                sched.reserve_per_seq,
                kv.capacity()
            );
        }

        let queue = AdmissionQueue::new(
            sched.order,
            admission_costs(sched, tasks, self.sampling.max_response),
        );
        let shared = Mutex::new(PipeShared {
            queue,
            sched,
            kv,
            results: (0..n).map(|_| None).collect(),
            refills: (0..workers).map(|_| VecDeque::new()).collect(),
            lane_live: vec![0; workers],
            lane_clock: 0,
            release_floor: 0,
            live_now: 0,
            peak_live: 0,
            prefill_queue: VecDeque::new(),
            prepared: BTreeMap::new(),
            prefill_submitted: 0,
            prefill_completed: 0,
            prefill_joined: 0,
            prefill_inflight_peak: 0,
            exec_retries: 0,
            failed_prepares: BTreeSet::new(),
            stream,
            workers_done: 0,
            workers_total: workers,
            failed: None,
        });
        let cv = Condvar::new();
        let (shared, cv) = (&shared, &cv);
        let policy = *self;

        // Fold any outcome — returned `Err` OR caught panic (with its
        // actual payload: injected-fault messages, violated `expect`s) —
        // into `PipeShared.failed` so parked peers and the executor bail
        // with the real cause instead of a generic note, then surface the
        // same message through the thread's own return value.
        fn settle<P, T>(
            shared: &Mutex<PipeShared<'_, P>>,
            cv: &Condvar,
            what: &str,
            out: std::thread::Result<Result<T>>,
        ) -> Result<T> {
            let out = match out {
                Ok(out) => out,
                Err(payload) => {
                    Err(anyhow::anyhow!("{what} panicked: {}", core::panic_msg(&*payload)))
                }
            };
            if let Err(e) = &out {
                // poison the run so parked peers (and the executor) bail
                // out instead of waiting on work that will never come
                if let Ok(mut sh) = shared.lock() {
                    if sh.failed.is_none() {
                        sh.failed = Some(format!("{e:#}"));
                    }
                }
                cv.notify_all();
            }
            out
        }
        let (joined, exec_joined) = std::thread::scope(|scope| {
            let exec_handle = prefill_backend.map(|eb| {
                scope.spawn(move || {
                    let mut fence = PanicFence { shared, cv, disarmed: false };
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        prefill_executor(
                            eb,
                            tasks,
                            policy.fault_retries,
                            policy.fault_policy.is_quarantine(),
                            shared,
                            cv,
                        )
                    }));
                    fence.disarmed = true;
                    drop(fence);
                    settle(shared, cv, "prefill executor", out)
                })
            });
            let handles: Vec<_> = backends
                .iter_mut()
                .enumerate()
                .map(|(me, b)| {
                    scope.spawn(move || {
                        let mut fence = PanicFence { shared, cv, disarmed: false };
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            policy.pipelined_worker(b, tasks, seed, seq_id_base, me, shared, cv)
                        }));
                        fence.disarmed = true;
                        drop(fence);
                        settle(shared, cv, "pipelined worker", out)
                    })
                })
                .collect();
            let joined = handles
                .into_iter()
                .map(|h| h.join())
                .collect::<Vec<_>>();
            // workers are all done (workers_done == total or failed), so
            // the executor's shutdown gate is open
            (joined, exec_handle.map(|h| h.join()))
        });

        let mut stats = RolloutStats::default();
        let mut makespan = 0u64;
        for res in joined {
            // catch_unwind already folded in-thread panics into Err; this
            // fallback only fires if the harness itself unwound, and still
            // surfaces the payload
            let (ws, finish) = res.unwrap_or_else(|p| {
                Err(anyhow::anyhow!("pipelined worker panicked: {}", core::panic_msg(&*p)))
            })?;
            stats.merge(&ws);
            makespan = makespan.max(finish);
        }
        if let Some(res) = exec_joined {
            res.unwrap_or_else(|p| {
                Err(anyhow::anyhow!("prefill executor panicked: {}", core::panic_msg(&*p)))
            })?;
        }
        stats.workers = workers;
        stats.modeled_makespan_ticks = makespan;
        let mut sh = shared
            .lock()
            .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))?;
        stats.peak_live_slots = stats.peak_live_slots.max(sh.peak_live);
        stats.async_prefills_submitted = sh.prefill_submitted;
        stats.async_prefills_completed = sh.prefill_completed;
        stats.async_prefill_inflight_peak = sh.prefill_inflight_peak;
        stats.retries += sh.exec_retries;
        debug_assert!(
            sh.prepared.is_empty() && sh.prefill_queue.is_empty() && sh.failed_prepares.is_empty(),
            "async prefills leaked past the drain"
        );
        let mut out = Vec::with_capacity(n);
        for (pos, seq) in sh.results.iter_mut().enumerate() {
            match seq.take() {
                Some(s) => out.push(s),
                None => bail!("pipelined rollout dropped task at position {pos}"),
            }
        }
        Ok((out, stats))
    }

    /// One pipelined worker lane: a continuous-style decode loop over its
    /// own backend, coordinating admission/release/growth/stealing
    /// through the shared state. Slot prefills: performed here at join
    /// time (sync — charged to this lane's clock) or awaited from the
    /// executor thread and applied (async — already charged to the shared
    /// prefill lane at issue). Returns its stats and its final virtual
    /// clock.
    #[allow(clippy::too_many_arguments)]
    fn pipelined_worker<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
        seq_id_base: u64,
        me: usize,
        shared: &Mutex<PipeShared<'_, B::Prepared>>,
        cv: &Condvar,
    ) -> Result<(RolloutStats, u64)> {
        let geom = Geometry::of(b);
        let r = geom.slots;
        let asynch = self.prefill.is_async();
        // chunked prefill (prefill-chunk-tokens > 0): pending refills stay
        // in the shared registry (and stay stealable), but the device work
        // happens in token-budgeted chunks on THIS lane's backend — the
        // partial KV lives in this lane's slot, so an in-progress chunk is
        // lane-pinned and never enters the steal surface. The async
        // executor is bypassed (chunks are cache-dependent, so there is no
        // cache-independent prepare to offload): refills carry
        // `ready_at = now` and `async_prefills_submitted` stays 0.
        let chunked = self.prefill_chunk_tokens > 0;
        let lock = || {
            shared
                .lock()
                .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))
        };
        // block until the executor delivers `pos` (async joins only): a
        // PHYSICAL wait with no virtual charge — the virtual lane already
        // accounted the prefill at issue time, so modeled stats stay
        // independent of real thread scheduling. `Ok(None)` means the
        // executor exhausted its retries on this prepare under
        // `fault-policy = quarantine`: the caller quarantines the task.
        let wait_prepared = |pos: usize| -> Result<Option<B::Prepared>> {
            let mut guard = lock()?;
            loop {
                if let Some(p) = guard.prepared.remove(&pos) {
                    guard.prefill_joined += 1;
                    return Ok(Some(p));
                }
                if guard.failed_prepares.remove(&pos) {
                    guard.prefill_joined += 1;
                    return Ok(None);
                }
                if let Some(e) = &guard.failed {
                    bail!("pipelined peer failed: {e}");
                }
                let (g, _) = cv
                    .wait_timeout(guard, Duration::from_millis(2))
                    .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))?;
                guard = g;
            }
        };

        let mut stats = RolloutStats { chunks: 1, workers: 1, ..RolloutStats::default() };
        // this lane's virtual clock (ticks on the backend's cost model)
        let mut now = 0u64;
        let stream = lock()?.stream.clone();
        let mut core = DecodeCore::new(geom, self.mode.is_sparse())
            .with_retries(self.fault_retries)
            .with_stream(stream);
        // prefill-once-attach-G, per lane (sync joins only: the async
        // executor's pipeline already overlaps prepares with decode, and
        // its payloads are keyed by task — attach-sharing there would
        // complicate the hand-off for a lane that never blocks anyway)
        let mut pcache: PrefillCache<B> =
            PrefillCache::new(!asynch && self.sharing.is_group()).with_retries(self.fault_retries);
        // slots whose row in `logp` is fresh (sampled at the loop top);
        // freshly joined slots carry an already-sampled token instead
        let mut decoded = vec![false; r];
        let mut logp: Vec<f32> = Vec::new();

        // ---- initial wave: admit a batch head, one batched prefill ------
        let mut wave = PrefillWave::new(&geom);
        {
            let mut guard = lock()?;
            while wave.count() < r {
                let Some(pos) = guard.admit_next(tasks, seq_id_base) else { break };
                let (idx, task) = tasks[pos];
                wave.push(&mut core, pos, idx, &task.prompt_ids, seed);
            }
            guard.lane_live[me] = wave.count();
            guard.snap_residency(&mut stats);
        }
        let w0 = wave.count();
        if w0 > 0 {
            // async: the batched prefill shares the single modeled prefill
            // lane with every other worker's; the decode lane blocks on it
            // (nothing to decode before the first logits anyway).
            // sync: this worker makes the call and its lane blocks for the
            // full cost.
            let ready = if asynch {
                Some(lock()?.lane_issue(now, geom.costs.prefill_ticks))
            } else {
                None
            };
            match wave.prefill(&core, b, &mut stats) {
                Ok(l) => {
                    logp = l;
                    if let Some(ready) = ready {
                        stats.prefill_blocked_ticks += ready - now;
                        now = ready;
                    } else {
                        stats.prefill_blocked_ticks += geom.costs.prefill_ticks;
                        now += geom.costs.prefill_ticks;
                    }
                    for d in decoded.iter_mut().take(w0) {
                        *d = true;
                    }
                }
                Err(e) if self.fault_policy.is_quarantine() => {
                    // the whole staged wave shared the failed call: release
                    // every member's admission, record the failures, and
                    // fall through to the main loop's empty-lane path
                    let _ = e;
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    for live in core.quarantine_live(sh.sched, sh.kv, seq_id_base, &mut stats)? {
                        sh.release_at(now);
                        sh.results[live.pos] = Some(live.gen);
                    }
                    sh.lane_live[me] = 0;
                    drop(guard);
                    cv.notify_all();
                }
                Err(e) => return Err(e),
            }
        }

        // at most one prompt mid-chunk on this lane (see `chunked` above)
        let mut chunk: Option<ChunkInProgress> = None;
        // per-step latency high-water: ticks this lane charges between
        // consecutive loop iterations. Initialized AFTER the wave so the
        // one-off batched prefill is excluded.
        let mut tick_mark = stats.decode_busy_ticks + stats.prefill_blocked_ticks;

        loop {
            let t = stats.decode_busy_ticks + stats.prefill_blocked_ticks;
            stats.max_step_ticks = stats.max_step_ticks.max(t - tick_mark);
            tick_mark = t;
            // streamed tokens carry this lane's virtual time (pure
            // observability — no scheduling decision reads it)
            core.clock = now;
            // ---- sample from fresh logits; release finishers ------------
            let mut released = false;
            for slot in 0..r {
                if !decoded[slot] {
                    continue;
                }
                decoded[slot] = false;
                let dist = &logp[slot * geom.vocab..(slot + 1) * geom.vocab];
                if let Some(done) = core.sample(self, slot, dist) {
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    sh.sched.release_seq(sh.kv, seq_id_base + done.pos as u64)?;
                    sh.release_at(now);
                    sh.lane_live[me] = core.occupied();
                    sh.results[done.pos] = Some(done.gen);
                    released = true;
                }
            }
            if released {
                cv.notify_all();
            }

            // ---- join refills whose virtual ready time has arrived ------
            let mut joins: Vec<PendingRefill> = Vec::new();
            {
                let mut guard = lock()?;
                if chunked {
                    // one refill leaves the (stealable) registry at a time,
                    // exactly when this lane starts chunking its prompt —
                    // from then on the partial KV pins it to this lane
                    if chunk.is_none()
                        && guard.refills[me].front().is_some_and(|p| p.ready_at <= now)
                    {
                        let p = guard.refills[me].pop_front().expect("checked front");
                        let slot = core.free_slot().expect(
                            "a free slot exists per pending refill (registry invariant)",
                        );
                        chunk = Some(ChunkInProgress { pos: p.pos, slot, offset: 0 });
                    }
                } else {
                    while guard.refills[me].front().is_some_and(|p| p.ready_at <= now) {
                        joins.push(guard.refills[me].pop_front().expect("checked front"));
                    }
                }
            }
            if let Some(c) = chunk.as_mut() {
                // advance the in-progress chunk by one token-budgeted step;
                // only the final chunk joins the decode batch (with a cache
                // and logits row bit-identical to a monolithic prefill)
                let (idx, task) = tasks[c.pos];
                match prefill_chunk_step(
                    b,
                    &geom,
                    c,
                    &task.prompt_ids,
                    self.prefill_chunk_tokens,
                    core.occupied(),
                    self.fault_retries,
                    &mut stats,
                ) {
                    Ok((row, ticks)) => {
                        now += ticks;
                        core.clock = now;
                        if let Some(row) = row {
                            stats.refills += 1;
                            let (pos, slot) = (c.pos, c.slot);
                            chunk = None;
                            if let Some(done) =
                                core.join(self, slot, pos, idx, &task.prompt_ids, &row, seed)
                            {
                                // degenerate single-token sequence
                                let mut guard = lock()?;
                                let sh = &mut *guard;
                                sh.sched.release_seq(sh.kv, seq_id_base + done.pos as u64)?;
                                sh.release_at(now);
                                sh.results[done.pos] = Some(done.gen);
                                sh.lane_live[me] = core.occupied();
                                drop(guard);
                                cv.notify_all();
                            } else {
                                decoded[slot] = false;
                                lock()?.lane_live[me] = core.occupied();
                            }
                        }
                    }
                    Err(e) if self.fault_policy.is_quarantine() => {
                        let _ = e;
                        let pos = c.pos;
                        chunk = None;
                        let mut guard = lock()?;
                        let sh = &mut *guard;
                        sh.sched.quarantine_seq(sh.kv, seq_id_base + pos as u64)?;
                        sh.release_at(now);
                        sh.results[pos] =
                            Some(GenSeq::failed_seq(idx, task.prompt_ids.clone()));
                        drop(guard);
                        stats.failed_tasks += 1;
                        cv.notify_all();
                    }
                    Err(e) => return Err(e),
                }
            }
            let mut joined_any = false;
            for p in joins {
                let slot = core
                    .free_slot()
                    .expect("a free slot exists per pending refill (registry invariant)");
                let (idx, task) = tasks[p.pos];
                let pi = &task.prompt_ids;
                // `None` = this refill's prefill is unrecoverable under
                // `fault-policy = quarantine` (executor marker, or an
                // exhausted inline call): quarantine the task below.
                let row: Option<Vec<f32>> = if asynch {
                    match wait_prepared(p.pos)? {
                        None => None, // executor-side exhaustion marker
                        Some(prepared) => {
                            let res = if stats.prefills == 0 {
                                // this lane's whole first wave was refused
                                // at the wall, so it has no live cache yet
                                // and the real backend's apply would
                                // reject: run the batched entry with just
                                // this prompt instead (batch-row
                                // independence makes the slot's logits
                                // identical) and drop the prepared payload
                                prefill_single_row(
                                    &geom,
                                    b,
                                    slot,
                                    pi,
                                    self.fault_retries,
                                    &mut stats,
                                )
                            } else {
                                match core::with_retries(
                                    self.fault_retries,
                                    geom.costs.slot_prefill_ticks,
                                    core::TickBucket::Prefill,
                                    &mut stats,
                                    || b.apply_prefill(slot, prepared.clone()),
                                ) {
                                    Ok(r) => {
                                        stats.slot_prefills += 1;
                                        Ok(r)
                                    }
                                    Err(e) => Err(e),
                                }
                            };
                            match res {
                                Ok(r) => Some(r),
                                Err(e) if self.fault_policy.is_quarantine() => {
                                    let _ = e;
                                    None
                                }
                                Err(e) => return Err(e),
                            }
                        }
                    }
                } else {
                    // sync: the device call happens here, on this worker,
                    // so the honest virtual charge lands on this lane
                    // (a shared attach is a slot write — attach_ticks)
                    let res = if stats.prefills == 0 {
                        // no live cache yet on this lane (first wave was
                        // refused): the batched entry bypasses — and does
                        // not seed — the share cache
                        prefill_single_row(&geom, b, slot, pi, self.fault_retries, &mut stats)
                            .map(|r| (r, false))
                    } else {
                        pcache.slot_prefill(b, slot, pi, &mut stats)
                    };
                    match res {
                        Ok((row, attached)) => {
                            let ticks = if attached {
                                geom.costs.attach_ticks
                            } else {
                                geom.costs.slot_prefill_ticks
                            };
                            stats.prefill_blocked_ticks += ticks;
                            now += ticks;
                            Some(row)
                        }
                        Err(e) if self.fault_policy.is_quarantine() => {
                            let _ = e;
                            None
                        }
                        Err(e) => return Err(e),
                    }
                };
                let Some(row) = row else {
                    // quarantine this refill: its admission is released,
                    // its result recorded failed, and the freed room wakes
                    // parked peers
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    sh.sched.quarantine_seq(sh.kv, seq_id_base + p.pos as u64)?;
                    sh.release_at(now);
                    sh.results[p.pos] = Some(GenSeq::failed_seq(idx, pi.clone()));
                    drop(guard);
                    stats.failed_tasks += 1;
                    cv.notify_all();
                    continue;
                };
                stats.refills += 1;
                core.clock = now;
                // identical per-token semantics to the continuous refill
                // path: first token from the slot-prefill logits
                if let Some(done) = core.join(self, slot, p.pos, idx, pi, &row, seed) {
                    // degenerate single-token sequence: release; the slot
                    // frees for the next admission pass below
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    sh.sched.release_seq(sh.kv, seq_id_base + done.pos as u64)?;
                    sh.release_at(now);
                    sh.results[done.pos] = Some(done.gen);
                    drop(guard);
                    cv.notify_all();
                    continue;
                }
                decoded[slot] = false;
                joined_any = true;
            }
            if joined_any {
                lock()?.lane_live[me] = core.occupied();
            }

            // ---- issue refills: admit + register (async: submit) --------
            {
                let mut guard = lock()?;
                let mut submitted = false;
                // an in-progress chunk owns a slot that neither `occupied`
                // nor the registry counts yet
                while core.occupied() + guard.refills[me].len() + (chunk.is_some() as usize) < r
                {
                    let Some(pos) = guard.admit_next(tasks, seq_id_base) else {
                        break; // queue empty, or wall: retry after releases
                    };
                    guard.issue_refill(
                        me,
                        pos,
                        now,
                        geom.costs.slot_prefill_ticks,
                        asynch && !chunked,
                    );
                    guard.snap_residency(&mut stats);
                    submitted = true;
                }
                drop(guard);
                if submitted && asynch {
                    cv.notify_all(); // wake the executor
                }
            }

            // ---- empty lane: wait, steal, or drain ----------------------
            if core.occupied() == 0 {
                if chunk.is_some() {
                    // the in-flight chunk is this lane's only live work:
                    // keep advancing it (each pass charges ticks, so the
                    // virtual clock moves and the loop cannot spin)
                    continue;
                }
                let mut guard = lock()?;
                if let Some(t) = guard.refills[me].front().map(|p| p.ready_at) {
                    // nothing decodable while the lane prefills: the
                    // decode lane waits for the earliest join (sync
                    // refills are ready immediately; stolen ones may
                    // carry a later ready_at)
                    drop(guard);
                    stats.prefill_blocked_ticks += t.saturating_sub(now);
                    now = now.max(t);
                    continue;
                }
                // The queue has work this lane cannot admit (a peer holds
                // the wall), or is empty while peers still hold pending
                // refills. Adopt queue work when it fits, steal a pending
                // refill from the most-loaded peer, or park until a
                // release (releases notify; the timeout re-checks
                // `failed` and the deadlock predicate, never aborting a
                // merely-slow run).
                let stall_start = now;
                let mut submitted = false;
                let got_work = loop {
                    if let Some(e) = &guard.failed {
                        bail!("pipelined peer failed: {e}");
                    }
                    if let Some(pos) = guard.admit_next(tasks, seq_id_base) {
                        // honest virtual time: this admission only became
                        // possible when a peer released KV
                        now = now.max(guard.release_floor);
                        guard.issue_refill(
                            me,
                            pos,
                            now,
                            geom.costs.slot_prefill_ticks,
                            asynch && !chunked,
                        );
                        guard.snap_residency(&mut stats);
                        submitted = asynch && !chunked;
                        break true;
                    }
                    if self.steal {
                        if let Some(p) = guard.steal_for(me) {
                            // adopt the refill: its admission charge, its
                            // prefill-lane slot, and (async) its prepared
                            // payload travel with it — the thief just
                            // inherits the wait for `ready_at`
                            guard.refills[me].push_back(p);
                            stats.steals += 1;
                            break true;
                        }
                    }
                    if guard.queue.is_empty() {
                        break false; // drained: worker done
                    }
                    // state-based deadlock check (NOT wall-clock based — a
                    // slow real backend may take arbitrarily long between
                    // releases): with no sequence admitted anywhere, no
                    // future release can ever free room, so a refusal now
                    // is a refusal forever.
                    if guard.live_now == 0 {
                        bail!(
                            "pipelined rollout stalled: {} pending but nothing \
                             admissible on an idle wall (reserve {} > free KV {})",
                            guard.queue.len(),
                            guard.sched.reserve_per_seq,
                            guard.kv.available()
                        );
                    }
                    let (g, _) = cv
                        .wait_timeout(guard, Duration::from_millis(2))
                        .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))?;
                    guard = g;
                };
                drop(guard);
                if submitted {
                    cv.notify_all(); // wake the executor, off the lock
                }
                if !got_work {
                    break; // queue drained: worker done (peers drain their own)
                }
                stats.sched_stall_ticks += now - stall_start;
                continue; // the pending refill joins at the loop top
            }

            // ---- compression trigger (the shared per-sequence rule). A
            // sequence still attached to a shared prefix forks
            // copy-on-write — an allocation that can stall at the wall
            // and preempt from the OWN batch, exactly like growth -------
            {
                let compressed = match core.compress_step(b, &mut stats) {
                    Ok(c) => c,
                    Err(e) if self.fault_policy.is_quarantine() => {
                        // batch fault: every live member of THIS lane
                        // shared the failed call; quarantine them all and
                        // fall through to the empty-lane path
                        let _ = e;
                        let mut guard = lock()?;
                        let sh = &mut *guard;
                        for live in
                            core.quarantine_live(sh.sched, sh.kv, seq_id_base, &mut stats)?
                        {
                            sh.release_at(now);
                            sh.results[live.pos] = Some(live.gen);
                        }
                        sh.lane_live[me] = 0;
                        drop(guard);
                        cv.notify_all();
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                if !compressed.is_empty() {
                    now += geom.costs.compress_ticks;
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    let evicted = core.compress_finish(
                        sh.sched,
                        sh.kv,
                        seq_id_base,
                        &compressed,
                        &mut stats,
                    )?;
                    let preempted = !evicted.is_empty();
                    for (slot, v) in evicted {
                        sh.release_at(now);
                        sh.queue.push_front(v.pos);
                        decoded[slot] = false;
                    }
                    sh.lane_live[me] = core.occupied();
                    drop(guard);
                    if preempted {
                        cv.notify_all();
                    }
                }
            }

            // ---- paged growth; stalls preempt from the OWN batch --------
            // (cross-worker caches are untouchable; freed pages help every
            // lane, so preemptions notify the pool)
            {
                let mut guard = lock()?;
                let sh = &mut *guard;
                let evicted = core.grow_step(sh.sched, sh.kv, seq_id_base, &mut stats)?;
                let preempted = !evicted.is_empty();
                for (slot, v) in evicted {
                    sh.release_at(now);
                    sh.queue.push_front(v.pos);
                    decoded[slot] = false;
                }
                sh.lane_live[me] = core.occupied();
                drop(guard);
                if preempted {
                    cv.notify_all();
                }
            }

            // ---- one decode step over the mixed batch -------------------
            if core.occupied() == 0 {
                continue; // growth evicted the whole batch: re-admit/wait
            }
            logp = match core.decode_step(b, &mut stats) {
                Ok(l) => l,
                Err(e) if self.fault_policy.is_quarantine() => {
                    let _ = e;
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    for live in core.quarantine_live(sh.sched, sh.kv, seq_id_base, &mut stats)? {
                        sh.release_at(now);
                        sh.results[live.pos] = Some(live.gen);
                    }
                    sh.lane_live[me] = 0;
                    drop(guard);
                    cv.notify_all();
                    continue; // empty lane: re-admit, steal, or drain
                }
                Err(e) => return Err(e),
            };
            now += geom.costs.decode_ticks;
            for slot in 0..r {
                decoded[slot] = core.slots[slot].is_some();
            }
        }

        // fold the final iteration's charges into the per-step high-water
        let t = stats.decode_busy_ticks + stats.prefill_blocked_ticks;
        stats.max_step_ticks = stats.max_step_ticks.max(t - tick_mark);

        // open the executor's shutdown gate (async: it exits once every
        // worker has drained and the request queue is empty)
        {
            let mut guard = lock()?;
            guard.workers_done += 1;
        }
        cv.notify_all();
        Ok((stats, now))
    }
}
