//! Autoregressive rollout engines (dense and sparse paths): one shared
//! decode core, three scheduling shells.
//!
//! * `core`       — THE decode-step state machine over `LiveSeq`
//!   (sample/append/grow/compress/finish): per-task RNG, sampling with
//!   log π_sparse recording (Eq. 2), EOS/cap handling, KV accounting,
//!   compression triggering, paged growth + preemption, and the decode
//!   invocation with its slot-step denominator accounting — shared
//!   verbatim by every engine.
//! * `static_`    — static chunked shell: a chunk of ≤ R sequences
//!   decodes until its slowest member finishes (the long-tail bubble).
//! * `continuous` — continuous batching with slot recycling: finished
//!   sequences release KV immediately and freed slots re-prefill in
//!   place; slot prefills still stall the one decode batch.
//! * `pipelined`  — N worker lanes over ONE shared scheduler/KV wall,
//!   with cross-worker work stealing for drained lanes (`steal`) and
//!   slot prefills either paid by the joining worker (`prefill = sync`)
//!   or run by a dedicated prefill-executor THREAD (`prefill = async`)
//!   so recycling overlaps decode for real.
//! * `stats`      — `RolloutStats`: occupancy, residency peaks, and the
//!   virtual-clock tick accounting behind the hermetic timing benches.
//!
//! Scheduling knobs (`steal`, `admission-order`, `prefill`) never change
//! tokens: per-task RNG streams (`task_rng`) make a task's sampling
//! randomness a pure function of (rollout seed, task index), never of
//! the slot, chunk, worker, admission order, prefill mode, or
//! steal/preemption schedule it experiences. Combined with batch-row
//! independence of the model, a given task emits identical
//! `response_ids` and `sampler_logp` under all engines — which keeps the
//! Eq. 2/5 correction math bit-reproducible and is what
//! `tests/engine_equivalence.rs` checks exhaustively over the full
//! {engine} × {steal} × {admission-order} × {prefill sync/async} grid.
//!
//! The sparse path realizes the paper's rollout: the cache holds at most
//! `budget + buffer` slots; whenever a sequence fills the buffer, the
//! compression artifact compacts it back to `budget` retained tokens.

pub mod core;
pub mod stats;

mod continuous;
mod pipelined;
mod static_;

pub use self::core::{sample_token, task_rng, GenSeq};
pub use self::stats::RolloutStats;

use anyhow::Result;

use crate::config::{FaultPolicy, PrefillMode, PrefixSharing, RolloutMode, SamplingConfig};
use crate::data::task::Task;
use crate::runtime::{ModelEngine, ParamsLit, Variant};

use super::backend::EngineBackend;
use super::kv_manager::KvMemoryManager;
use super::scheduler::Scheduler;

/// The backend-independent rollout policy: mode + sampling + the
/// engine-scheduling switches that must never change tokens. Holds every
/// engine entry point (`rollout_static`, `rollout_static_queue`,
/// `rollout_continuous`, `rollout_pipelined`) over the shared decode
/// core; `RolloutEngine` binds it to the AOT artifacts, the test harness
/// binds it to the mock backend.
#[derive(Debug, Clone, Copy)]
pub struct RolloutPolicy {
    pub mode: RolloutMode,
    pub sampling: SamplingConfig,
    /// Cross-worker work stealing (pipelined engine only; `steal` config
    /// knob, default on): a drained lane adopts a not-yet-prefilled
    /// refill from the most-loaded peer instead of parking on the
    /// condvar. Scheduling-only — tokens are steal-invariant.
    pub steal: bool,
    /// Slot-prefill execution for the pipelined engine (`prefill` config
    /// knob, default sync = the original blocking behavior): sync makes
    /// the joining worker pay the device call on its own lane; async
    /// runs a dedicated prefill-executor thread so the call overlaps
    /// decode. Scheduling-only — tokens are mode-invariant.
    pub prefill: PrefillMode,
    /// Prompt-prefix sharing (`prefix-sharing` config knob, default off):
    /// under `group`, refills of an already-seen prompt attach a cached
    /// prepared prefill instead of re-running the model
    /// (prefill-once-attach-G on the sync paths), and — together with
    /// `admission = paged` — the scheduler charges a GRPO group's shared
    /// prompt pages once via the refcounted pool. Scheduling/memory-only —
    /// tokens are sharing-invariant.
    pub sharing: PrefixSharing,
    /// Bounded retry budget for failed backend calls (`fault-retries`
    /// config knob, default 0 = seed behavior: first error is final).
    /// Retries re-execute the identical call — backends fail before any
    /// side effect — so tokens are retry-invariant; each retried attempt
    /// charges virtual-clock backoff to the calling lane and counts in
    /// `RolloutStats::retries`.
    pub fault_retries: usize,
    /// Token budget per device step for chunked prefill
    /// (`prefill-chunk-tokens` config knob, default 0 = monolithic seed
    /// behavior): with a budget N, the continuous and pipelined shells
    /// stop issuing whole-prompt slot prefills and instead pack each
    /// engine step with the decode batch plus one ≤ N-token chunk of the
    /// scheduler's cheapest pending prompt, bounding per-step latency
    /// (`RolloutStats::max_step_ticks`). Scheduling-only — the completed
    /// chunked cache and first-token logits are bit-identical to a
    /// monolithic prefill, so tokens are budget-invariant. The static
    /// shell ignores it (no slot refills to chunk).
    pub prefill_chunk_tokens: usize,
    /// What exhausted retries do (`fault-policy` config knob, default
    /// abort = seed behavior): abort kills the batch with the error;
    /// quarantine releases the failed task (slot, KV pages, scheduler
    /// admission — conservation holds), marks its `GenSeq.failed`, and
    /// finishes the batch.
    pub fault_policy: FaultPolicy,
}

impl RolloutPolicy {
    pub fn new(mode: RolloutMode, sampling: SamplingConfig) -> Self {
        RolloutPolicy {
            mode,
            sampling,
            steal: true,
            prefill: PrefillMode::Sync,
            sharing: PrefixSharing::Off,
            fault_retries: 0,
            prefill_chunk_tokens: 0,
            fault_policy: FaultPolicy::Abort,
        }
    }

    /// Toggle pipelined work stealing (builder style; see `steal`).
    pub fn with_steal(mut self, steal: bool) -> Self {
        self.steal = steal;
        self
    }

    /// Select the pipelined slot-prefill mode (builder style; see
    /// `prefill`).
    pub fn with_prefill(mut self, prefill: PrefillMode) -> Self {
        self.prefill = prefill;
        self
    }

    /// Select prompt-prefix sharing (builder style; see `sharing`).
    pub fn with_sharing(mut self, sharing: PrefixSharing) -> Self {
        self.sharing = sharing;
        self
    }

    /// Set the bounded retry budget (builder style; see `fault_retries`).
    pub fn with_fault_retries(mut self, retries: usize) -> Self {
        self.fault_retries = retries;
        self
    }

    /// Set the chunked-prefill token budget (builder style; see
    /// `prefill_chunk_tokens`).
    pub fn with_prefill_chunk_tokens(mut self, tokens: usize) -> Self {
        self.prefill_chunk_tokens = tokens;
        self
    }

    /// Select the exhausted-retries policy (builder style; see
    /// `fault_policy`).
    pub fn with_fault_policy(mut self, policy: FaultPolicy) -> Self {
        self.fault_policy = policy;
        self
    }
}

/// The artifact-bound rollout engine for one model + mode.
pub struct RolloutEngine<'a> {
    pub engine: &'a ModelEngine,
    pub mode: RolloutMode,
    pub sampling: SamplingConfig,
    /// Pipelined work stealing (see `RolloutPolicy::steal`).
    pub steal: bool,
    /// Pipelined slot-prefill mode (see `RolloutPolicy::prefill`).
    pub prefill: PrefillMode,
    /// Prompt-prefix sharing (see `RolloutPolicy::sharing`).
    pub sharing: PrefixSharing,
    /// Bounded retry budget (see `RolloutPolicy::fault_retries`).
    pub fault_retries: usize,
    /// Chunked-prefill token budget (see
    /// `RolloutPolicy::prefill_chunk_tokens`).
    pub prefill_chunk_tokens: usize,
    /// Exhausted-retries policy (see `RolloutPolicy::fault_policy`).
    pub fault_policy: FaultPolicy,
}

impl<'a> RolloutEngine<'a> {
    pub fn new(engine: &'a ModelEngine, mode: RolloutMode, sampling: SamplingConfig) -> Self {
        RolloutEngine {
            engine,
            mode,
            sampling,
            steal: true,
            prefill: PrefillMode::Sync,
            sharing: PrefixSharing::Off,
            fault_retries: 0,
            prefill_chunk_tokens: 0,
            fault_policy: FaultPolicy::Abort,
        }
    }

    /// Toggle pipelined work stealing (builder style).
    pub fn with_steal(mut self, steal: bool) -> Self {
        self.steal = steal;
        self
    }

    /// Select the pipelined slot-prefill mode (builder style).
    pub fn with_prefill(mut self, prefill: PrefillMode) -> Self {
        self.prefill = prefill;
        self
    }

    /// Select prompt-prefix sharing (builder style).
    pub fn with_sharing(mut self, sharing: PrefixSharing) -> Self {
        self.sharing = sharing;
        self
    }

    /// Set the bounded retry budget (builder style).
    pub fn with_fault_retries(mut self, retries: usize) -> Self {
        self.fault_retries = retries;
        self
    }

    /// Set the chunked-prefill token budget (builder style).
    pub fn with_prefill_chunk_tokens(mut self, tokens: usize) -> Self {
        self.prefill_chunk_tokens = tokens;
        self
    }

    /// Select the exhausted-retries policy (builder style).
    pub fn with_fault_policy(mut self, policy: FaultPolicy) -> Self {
        self.fault_policy = policy;
        self
    }

    pub fn policy(&self) -> RolloutPolicy {
        RolloutPolicy::new(self.mode, self.sampling)
            .with_steal(self.steal)
            .with_prefill(self.prefill)
            .with_sharing(self.sharing)
            .with_fault_retries(self.fault_retries)
            .with_prefill_chunk_tokens(self.prefill_chunk_tokens)
            .with_fault_policy(self.fault_policy)
    }

    pub fn variant(&self) -> Variant {
        if self.mode.is_sparse() {
            Variant::Sparse
        } else {
            Variant::Dense
        }
    }

    /// Roll out one static chunk of tasks (≤ decode_batch sequences; the
    /// scheduler guarantees admission). `seed` is the rollout seed feeding
    /// the per-task RNG streams.
    pub fn rollout_chunk(
        &self,
        params: &[f32],
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<Vec<GenSeq>> {
        // weights are uploaded once per chunk, not once per decode step
        let params = ParamsLit::new(params);
        self.rollout_chunk_lit(&params, tasks, seed)
    }

    /// Same as `rollout_chunk` but with pre-uploaded weights (callers that
    /// run many chunks per step share one upload).
    pub fn rollout_chunk_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<Vec<GenSeq>> {
        Ok(self.rollout_chunk_stats_lit(params, tasks, seed)?.0)
    }

    /// Static chunk rollout returning occupancy statistics as well.
    pub fn rollout_chunk_stats_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backend = EngineBackend::new(self.engine, params, self.mode);
        self.policy().rollout_static(&mut backend, tasks, seed)
    }

    /// Static chunked rollout over the whole pending queue (any length).
    /// See `RolloutPolicy::rollout_static_queue`.
    pub fn rollout_static_queue_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backend = EngineBackend::new(self.engine, params, self.mode);
        self.policy()
            .rollout_static_queue(&mut backend, tasks, seed, sched, kv, seq_id_base)
    }

    /// Continuous-batching rollout over the whole pending queue (any
    /// length), recycling slots as sequences finish. See
    /// `RolloutPolicy::rollout_continuous`.
    pub fn rollout_continuous_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backend = EngineBackend::new(self.engine, params, self.mode);
        self.policy()
            .rollout_continuous(&mut backend, tasks, seed, sched, kv, seq_id_base)
    }

    /// Pipelined rollout over the whole pending queue: `workers` decode
    /// lanes (one `EngineBackend` each, all over this engine's artifacts)
    /// against the shared scheduler/wall — plus, under `prefill = async`,
    /// one extra `EngineBackend` for the dedicated prefill-executor
    /// thread. See `RolloutPolicy::rollout_pipelined`. This is the
    /// "handle story" for the production path: `ModelEngine` is `Sync`
    /// (executable cache behind a mutex), so N worker threads — and the
    /// executor — may each own an `EngineBackend` borrowing the same
    /// engine + uploaded weights.
    #[allow(clippy::too_many_arguments)]
    pub fn rollout_pipelined_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
        workers: usize,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backends: Vec<EngineBackend> = (0..workers.max(1))
            .map(|_| EngineBackend::new(self.engine, params, self.mode))
            .collect();
        if self.prefill.is_async() {
            let mut exec = EngineBackend::new(self.engine, params, self.mode);
            self.policy()
                .rollout_pipelined(&mut backends, Some(&mut exec), tasks, seed, sched, kv, seq_id_base)
        } else {
            self.policy()
                .rollout_pipelined(&mut backends, None, tasks, seed, sched, kv, seq_id_base)
        }
    }
}
