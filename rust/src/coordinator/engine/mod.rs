//! Autoregressive rollout engines (dense and sparse paths): one shared
//! decode core, three scheduling shells.
//!
//! * `core`       — THE decode-step state machine over `LiveSeq`
//!   (sample/append/grow/compress/finish): per-task RNG, sampling with
//!   log π_sparse recording (Eq. 2), EOS/cap handling, KV accounting,
//!   compression triggering, paged growth + preemption, and the decode
//!   invocation with its slot-step denominator accounting — shared
//!   verbatim by every engine.
//! * `static_`    — static chunked shell: a chunk of ≤ R sequences
//!   decodes until its slowest member finishes (the long-tail bubble).
//! * `continuous` — continuous batching with slot recycling: finished
//!   sequences release KV immediately and freed slots re-prefill in
//!   place; slot prefills still stall the one decode batch.
//! * `pipelined`  — N worker lanes over ONE shared scheduler/KV wall,
//!   with cross-worker work stealing for drained lanes (`steal`) and
//!   slot prefills either paid by the joining worker (`prefill = sync`)
//!   or run by a dedicated prefill-executor THREAD (`prefill = async`)
//!   so recycling overlaps decode for real.
//! * `stats`      — `RolloutStats`: occupancy, residency peaks, and the
//!   virtual-clock tick accounting behind the hermetic timing benches.
//!
//! Scheduling knobs (`steal`, `admission-order`, `prefill`) never change
//! tokens: per-task RNG streams (`task_rng`) make a task's sampling
//! randomness a pure function of (rollout seed, task index), never of
//! the slot, chunk, worker, admission order, prefill mode, or
//! steal/preemption schedule it experiences. Combined with batch-row
//! independence of the model, a given task emits identical
//! `response_ids` and `sampler_logp` under all engines — which keeps the
//! Eq. 2/5 correction math bit-reproducible and is what
//! `tests/engine_equivalence.rs` checks exhaustively over the full
//! {engine} × {steal} × {admission-order} × {prefill sync/async} grid.
//!
//! The sparse path realizes the paper's rollout: the cache holds at most
//! `budget + buffer` slots; whenever a sequence fills the buffer, the
//! compression artifact compacts it back to `budget` retained tokens.

pub mod core;
pub mod stats;

mod continuous;
mod pipelined;
mod static_;

pub use self::core::{sample_token, task_rng, GenSeq, StreamHub, TokenEvent};
pub use self::stats::{LatencyHistogram, RolloutStats};

use anyhow::Result;

use crate::config::{
    EngineKind, ExperimentConfig, FaultPolicy, PrefillMode, PrefixSharing, RolloutMode,
    SamplingConfig,
};
use crate::data::task::Task;
use crate::runtime::{ModelEngine, ParamsLit, Variant};

use super::backend::EngineBackend;
use super::kv_manager::KvMemoryManager;
use super::scheduler::Scheduler;

/// The per-rollout mutable context every queue engine needs, as one
/// borrow-struct: the scheduler, the KV wall it admits against, the
/// sequence-id namespace base, and (when a serving front-end subscribed)
/// the live token sink. This is the API collapse the engine entry points
/// were asking for — one `RolloutCtx` travels where the positional
/// `(sched, kv, seq_id_base)` tail used to, and new per-run state (like
/// `stream`) lands here instead of rippling another argument through
/// every engine signature and call site.
pub struct RolloutCtx<'c> {
    pub sched: &'c mut Scheduler,
    pub kv: &'c mut KvMemoryManager,
    /// Namespaces this rollout's sequence ids within `kv` (callers running
    /// several rollouts against one wall pass disjoint bases; 0 otherwise).
    pub seq_id_base: u64,
    /// Live per-token streaming sink; `None` (the closed-batch default)
    /// makes streaming a strict no-op.
    pub stream: Option<StreamHub>,
}

impl<'c> RolloutCtx<'c> {
    pub fn new(sched: &'c mut Scheduler, kv: &'c mut KvMemoryManager) -> RolloutCtx<'c> {
        RolloutCtx { sched, kv, seq_id_base: 0, stream: None }
    }

    /// Set the sequence-id namespace base (builder style).
    pub fn with_base(mut self, seq_id_base: u64) -> Self {
        self.seq_id_base = seq_id_base;
        self
    }

    /// Attach a live token sink (builder style).
    pub fn with_stream(mut self, stream: StreamHub) -> Self {
        self.stream = Some(stream);
        self
    }
}

/// The backend-independent rollout policy: mode + sampling + the
/// engine-scheduling switches that must never change tokens. Holds every
/// engine entry point (`rollout_static`, `rollout_static_queue`,
/// `rollout_continuous`, `rollout_pipelined`) over the shared decode
/// core; `RolloutEngine` binds it to the AOT artifacts, the test harness
/// binds it to the mock backend.
#[derive(Debug, Clone, Copy)]
pub struct RolloutPolicy {
    pub mode: RolloutMode,
    pub sampling: SamplingConfig,
    /// Cross-worker work stealing (pipelined engine only; `steal` config
    /// knob, default on): a drained lane adopts a not-yet-prefilled
    /// refill from the most-loaded peer instead of parking on the
    /// condvar. Scheduling-only — tokens are steal-invariant.
    pub steal: bool,
    /// Slot-prefill execution for the pipelined engine (`prefill` config
    /// knob, default sync = the original blocking behavior): sync makes
    /// the joining worker pay the device call on its own lane; async
    /// runs a dedicated prefill-executor thread so the call overlaps
    /// decode. Scheduling-only — tokens are mode-invariant.
    pub prefill: PrefillMode,
    /// Prompt-prefix sharing (`prefix-sharing` config knob, default off):
    /// under `group`, refills of an already-seen prompt attach a cached
    /// prepared prefill instead of re-running the model
    /// (prefill-once-attach-G on the sync paths), and — together with
    /// `admission = paged` — the scheduler charges a GRPO group's shared
    /// prompt pages once via the refcounted pool. Scheduling/memory-only —
    /// tokens are sharing-invariant.
    pub sharing: PrefixSharing,
    /// Bounded retry budget for failed backend calls (`fault-retries`
    /// config knob, default 0 = seed behavior: first error is final).
    /// Retries re-execute the identical call — backends fail before any
    /// side effect — so tokens are retry-invariant; each retried attempt
    /// charges virtual-clock backoff to the calling lane and counts in
    /// `RolloutStats::retries`.
    pub fault_retries: usize,
    /// Token budget per device step for chunked prefill
    /// (`prefill-chunk-tokens` config knob, default 0 = monolithic seed
    /// behavior): with a budget N, the continuous and pipelined shells
    /// stop issuing whole-prompt slot prefills and instead pack each
    /// engine step with the decode batch plus one ≤ N-token chunk of the
    /// scheduler's cheapest pending prompt, bounding per-step latency
    /// (`RolloutStats::max_step_ticks`). Scheduling-only — the completed
    /// chunked cache and first-token logits are bit-identical to a
    /// monolithic prefill, so tokens are budget-invariant. The static
    /// shell ignores it (no slot refills to chunk).
    pub prefill_chunk_tokens: usize,
    /// What exhausted retries do (`fault-policy` config knob, default
    /// abort = seed behavior): abort kills the batch with the error;
    /// quarantine releases the failed task (slot, KV pages, scheduler
    /// admission — conservation holds), marks its `GenSeq.failed`, and
    /// finishes the batch.
    pub fault_policy: FaultPolicy,
}

impl RolloutPolicy {
    pub fn new(mode: RolloutMode, sampling: SamplingConfig) -> Self {
        RolloutPolicy {
            mode,
            sampling,
            steal: true,
            prefill: PrefillMode::Sync,
            sharing: PrefixSharing::Off,
            fault_retries: 0,
            prefill_chunk_tokens: 0,
            fault_policy: FaultPolicy::Abort,
        }
    }

    /// The policy an experiment config describes, in one step — the
    /// construction-site replacement for chaining every `with_*` setter
    /// (which had to grow at each call site whenever a knob landed).
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        RolloutPolicy {
            mode: cfg.mode,
            sampling: cfg.sampling,
            steal: cfg.steal,
            prefill: cfg.prefill,
            sharing: cfg.memory.prefix_sharing,
            fault_retries: cfg.fault_retries,
            prefill_chunk_tokens: cfg.prefill_chunk_tokens,
            fault_policy: cfg.fault_policy,
        }
    }

    /// Toggle pipelined work stealing (builder style; see `steal`).
    pub fn with_steal(mut self, steal: bool) -> Self {
        self.steal = steal;
        self
    }

    /// Select the pipelined slot-prefill mode (builder style; see
    /// `prefill`).
    pub fn with_prefill(mut self, prefill: PrefillMode) -> Self {
        self.prefill = prefill;
        self
    }

    /// Select prompt-prefix sharing (builder style; see `sharing`).
    pub fn with_sharing(mut self, sharing: PrefixSharing) -> Self {
        self.sharing = sharing;
        self
    }

    /// Set the bounded retry budget (builder style; see `fault_retries`).
    pub fn with_fault_retries(mut self, retries: usize) -> Self {
        self.fault_retries = retries;
        self
    }

    /// Set the chunked-prefill token budget (builder style; see
    /// `prefill_chunk_tokens`).
    pub fn with_prefill_chunk_tokens(mut self, tokens: usize) -> Self {
        self.prefill_chunk_tokens = tokens;
        self
    }

    /// Select the exhausted-retries policy (builder style; see
    /// `fault_policy`).
    pub fn with_fault_policy(mut self, policy: FaultPolicy) -> Self {
        self.fault_policy = policy;
        self
    }
}

/// The artifact-bound rollout engine for one model + mode.
pub struct RolloutEngine<'a> {
    pub engine: &'a ModelEngine,
    pub mode: RolloutMode,
    pub sampling: SamplingConfig,
    /// Pipelined work stealing (see `RolloutPolicy::steal`).
    pub steal: bool,
    /// Pipelined slot-prefill mode (see `RolloutPolicy::prefill`).
    pub prefill: PrefillMode,
    /// Prompt-prefix sharing (see `RolloutPolicy::sharing`).
    pub sharing: PrefixSharing,
    /// Bounded retry budget (see `RolloutPolicy::fault_retries`).
    pub fault_retries: usize,
    /// Chunked-prefill token budget (see
    /// `RolloutPolicy::prefill_chunk_tokens`).
    pub prefill_chunk_tokens: usize,
    /// Exhausted-retries policy (see `RolloutPolicy::fault_policy`).
    pub fault_policy: FaultPolicy,
}

impl<'a> RolloutEngine<'a> {
    pub fn new(engine: &'a ModelEngine, mode: RolloutMode, sampling: SamplingConfig) -> Self {
        RolloutEngine {
            engine,
            mode,
            sampling,
            steal: true,
            prefill: PrefillMode::Sync,
            sharing: PrefixSharing::Off,
            fault_retries: 0,
            prefill_chunk_tokens: 0,
            fault_policy: FaultPolicy::Abort,
        }
    }

    /// The engine an experiment config describes, bound to `engine`'s
    /// artifacts — one step instead of the ever-growing `with_*` chain.
    pub fn from_config(engine: &'a ModelEngine, cfg: &ExperimentConfig) -> Self {
        let p = RolloutPolicy::from_config(cfg);
        RolloutEngine {
            engine,
            mode: p.mode,
            sampling: p.sampling,
            steal: p.steal,
            prefill: p.prefill,
            sharing: p.sharing,
            fault_retries: p.fault_retries,
            prefill_chunk_tokens: p.prefill_chunk_tokens,
            fault_policy: p.fault_policy,
        }
    }

    /// Toggle pipelined work stealing (builder style).
    pub fn with_steal(mut self, steal: bool) -> Self {
        self.steal = steal;
        self
    }

    /// Select the pipelined slot-prefill mode (builder style).
    pub fn with_prefill(mut self, prefill: PrefillMode) -> Self {
        self.prefill = prefill;
        self
    }

    /// Select prompt-prefix sharing (builder style).
    pub fn with_sharing(mut self, sharing: PrefixSharing) -> Self {
        self.sharing = sharing;
        self
    }

    /// Set the bounded retry budget (builder style).
    pub fn with_fault_retries(mut self, retries: usize) -> Self {
        self.fault_retries = retries;
        self
    }

    /// Set the chunked-prefill token budget (builder style).
    pub fn with_prefill_chunk_tokens(mut self, tokens: usize) -> Self {
        self.prefill_chunk_tokens = tokens;
        self
    }

    /// Select the exhausted-retries policy (builder style).
    pub fn with_fault_policy(mut self, policy: FaultPolicy) -> Self {
        self.fault_policy = policy;
        self
    }

    pub fn policy(&self) -> RolloutPolicy {
        RolloutPolicy::new(self.mode, self.sampling)
            .with_steal(self.steal)
            .with_prefill(self.prefill)
            .with_sharing(self.sharing)
            .with_fault_retries(self.fault_retries)
            .with_prefill_chunk_tokens(self.prefill_chunk_tokens)
            .with_fault_policy(self.fault_policy)
    }

    pub fn variant(&self) -> Variant {
        if self.mode.is_sparse() {
            Variant::Sparse
        } else {
            Variant::Dense
        }
    }

    /// Roll out one static chunk of tasks (≤ decode_batch sequences; the
    /// scheduler guarantees admission). `seed` is the rollout seed feeding
    /// the per-task RNG streams.
    pub fn rollout_chunk(
        &self,
        params: &[f32],
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<Vec<GenSeq>> {
        // weights are uploaded once per chunk, not once per decode step
        let params = ParamsLit::new(params);
        self.rollout_chunk_lit(&params, tasks, seed)
    }

    /// Same as `rollout_chunk` but with pre-uploaded weights (callers that
    /// run many chunks per step share one upload).
    pub fn rollout_chunk_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<Vec<GenSeq>> {
        Ok(self.rollout_chunk_stats_lit(params, tasks, seed)?.0)
    }

    /// Static chunk rollout returning occupancy statistics as well.
    pub fn rollout_chunk_stats_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backend = EngineBackend::new(self.engine, params, self.mode);
        self.policy().rollout_static(&mut backend, tasks, seed)
    }

    /// Open a rollout session over pre-uploaded weights: bind the engine
    /// shell to dispatch on, the pipelined lane count (ignored by the
    /// serial shells), and the per-run context. The session is the single
    /// queue-rollout entry point — callers that used to pick one of three
    /// seven-argument `rollout_*_lit` methods now build a `RolloutCtx` and
    /// call [`RolloutSession::run`].
    pub fn session<'p, 'c>(
        &self,
        params: &'p ParamsLit,
        kind: EngineKind,
        workers: usize,
        ctx: RolloutCtx<'c>,
    ) -> RolloutSession<'a, 'p, 'c> {
        RolloutSession {
            model: self.engine,
            mode: self.mode,
            policy: self.policy(),
            params,
            kind,
            workers,
            ctx,
        }
    }
}

/// One prepared queue rollout: the artifact binding, the engine shell to
/// dispatch on, the lane count, and the borrowed per-run context, behind
/// a single `run(tasks, seed)` entry point. Built by
/// [`RolloutEngine::session`]. The pipelined shell gets `workers.max(1)`
/// decode lanes (one `EngineBackend` each over the same artifacts) —
/// plus, under `prefill = async`, one extra lane for the dedicated
/// prefill-executor thread. This is the "handle story" for the
/// production path: `ModelEngine` is `Sync` (executable cache behind a
/// mutex), so N worker threads — and the executor — may each own an
/// `EngineBackend` borrowing the same engine + uploaded weights.
pub struct RolloutSession<'a, 'p, 'c> {
    model: &'a ModelEngine,
    mode: RolloutMode,
    policy: RolloutPolicy,
    params: &'p ParamsLit,
    kind: EngineKind,
    workers: usize,
    ctx: RolloutCtx<'c>,
}

impl RolloutSession<'_, '_, '_> {
    /// Run `tasks` to completion under the session's shell. Tokens are
    /// shell-invariant (per-task RNG); the stats are the shell's own
    /// virtual-clock accounting.
    pub fn run(self, tasks: &[(usize, &Task)], seed: u64) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let RolloutSession { model, mode, policy, params, kind, workers, ctx } = self;
        match kind {
            EngineKind::Static => {
                let mut backend = EngineBackend::new(model, params, mode);
                policy.rollout_static_queue(&mut backend, tasks, seed, ctx)
            }
            EngineKind::Continuous => {
                let mut backend = EngineBackend::new(model, params, mode);
                policy.rollout_continuous(&mut backend, tasks, seed, ctx)
            }
            EngineKind::Pipelined => {
                let mut backends: Vec<EngineBackend> = (0..workers.max(1))
                    .map(|_| EngineBackend::new(model, params, mode))
                    .collect();
                if policy.prefill.is_async() {
                    let mut exec = EngineBackend::new(model, params, mode);
                    policy.rollout_pipelined(&mut backends, Some(&mut exec), tasks, seed, ctx)
                } else {
                    policy.rollout_pipelined(&mut backends, None, tasks, seed, ctx)
                }
            }
        }
    }
}
