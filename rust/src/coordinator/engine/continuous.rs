//! Continuous-batching engine shell with slot recycling: the moment a
//! sequence finishes, its KV reservation is released, the next pending
//! prompt is admitted and prefilled *into that slot in place*, and the
//! mixed batch keeps decoding. Total decode steps drop from
//! Σ_chunks max(len) to the list-scheduling makespan of the per-sequence
//! decode costs — strictly better whenever response lengths are skewed.
//! But every slot prefill still stalls the whole decode batch (the bubble
//! the pipelined engine removes). All per-token semantics live in the
//! shared decode core.

use anyhow::{bail, Result};

use crate::data::task::Task;

use super::super::backend::RolloutBackend;
use super::super::scheduler::AdmissionQueue;
use super::core::{
    admission_costs, admit_next, prefill_chunk_step, snap_residency, ChunkInProgress,
    DecodeCore, GenSeq, Geometry, PrefillCache, PrefillWave,
};
use super::stats::RolloutStats;
use super::{RolloutCtx, RolloutPolicy};

impl RolloutPolicy {
    /// Continuous-batching rollout with slot recycling over an arbitrarily
    /// long task queue. Admission is per sequence: each admitted sequence
    /// reserves its admission charge with the scheduler/manager, and the
    /// reservation is released the moment the sequence finishes — not when
    /// the whole batch drains. Freed slots are immediately re-prefilled
    /// (in place) with the scheduler's next pick (`admission-order`:
    /// fifo, or shortest-predicted-residency-first).
    ///
    /// Sequences are returned in task order. Total decode steps equal the
    /// list-scheduling makespan of per-sequence decode costs over the
    /// admission order, which `Scheduler::predicted_decode_steps` computes
    /// in closed form.
    pub fn rollout_continuous<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
        ctx: RolloutCtx,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let RolloutCtx { sched, kv, seq_id_base, stream } = ctx;
        let geom = Geometry::of(b);
        let n = tasks.len();
        let mut stats = RolloutStats { chunks: 1, workers: 1, ..RolloutStats::default() };
        if n == 0 {
            return Ok((vec![], stats));
        }

        // Paged admission must be able to grow a lone sequence to its
        // worst-case residency, or the preempt/requeue path could thrash
        // forever on a wall that cannot hold even one sequence.
        if kv.pages_for(sched.reserve_per_seq) > kv.total_pages() {
            bail!(
                "continuous rollout deadlock: one sequence may need {} KV tokens \
                 but the wall holds only {}",
                sched.reserve_per_seq,
                kv.capacity()
            );
        }

        let mut results: Vec<Option<GenSeq>> = (0..n).map(|_| None).collect();
        let mut queue = AdmissionQueue::new(
            sched.order,
            admission_costs(sched, tasks, self.sampling.max_response),
        );
        let mut core = DecodeCore::new(geom, self.mode.is_sparse())
            .with_retries(self.fault_retries)
            .with_stream(stream);
        // prefill-once-attach-G: under `prefix-sharing = group`, refills of
        // an already-prepared prompt attach the cached payload instead of
        // re-running the model (token-identical by the prepare/apply
        // contract; only the modeled latency differs)
        let mut pcache: PrefillCache<B> =
            PrefillCache::new(self.sharing.is_group()).with_retries(self.fault_retries);

        // ---- initial wave: one batched prefill over the admissible head.
        // A wave prefill that exhausts its retries under `fault-policy =
        // quarantine` fails the whole staged wave (every member shared the
        // failed call) and the loop stages the next admissible wave; with
        // the default abort policy the error propagates unchanged.
        let mut logp: Vec<f32> = Vec::new();
        loop {
            let mut wave = PrefillWave::new(&geom);
            while wave.count() < geom.slots {
                let Some(pos) = admit_next(sched, kv, &mut queue, tasks, seq_id_base)
                else {
                    break;
                };
                let (idx, task) = tasks[pos];
                wave.push(&mut core, pos, idx, &task.prompt_ids, seed);
            }
            if wave.count() == 0 {
                bail!(
                    "continuous rollout deadlock: cannot admit any sequence \
                     (reserve {} > free KV {} of {})",
                    sched.reserve_per_seq,
                    kv.available(),
                    kv.capacity()
                );
            }
            match wave.prefill(&core, b, &mut stats) {
                Ok(l) => {
                    logp = l;
                    // serial lane: the decode batch blocks on its own prefill
                    stats.prefill_blocked_ticks += geom.costs.prefill_ticks;
                    snap_residency(kv, &mut stats);
                    break;
                }
                Err(e) if self.fault_policy.is_quarantine() => {
                    let _ = e;
                    for live in core.quarantine_live(sched, kv, seq_id_base, &mut stats)? {
                        results[live.pos] = Some(live.gen);
                    }
                    if queue.is_empty() {
                        break; // every task quarantined: nothing to decode
                    }
                }
                Err(e) => return Err(e),
            }
        }

        // ---- chunked-prefill bookkeeping (prefill-chunk-tokens > 0): at
        // most one prompt is mid-chunk at a time on this serial lane; its
        // partial KV lives in `chunk.slot`, so the task is committed to
        // that slot until the final chunk joins it into the decode batch.
        let mut chunk: Option<ChunkInProgress> = None;
        // per-step latency high-water: ticks charged between consecutive
        // loop iterations (one virtual-clock engine step). Initialized
        // AFTER the wave so the one-off batched prefill is excluded.
        let mut tick_mark = stats.decode_busy_ticks + stats.prefill_blocked_ticks;

        loop {
            let t = stats.decode_busy_ticks + stats.prefill_blocked_ticks;
            stats.max_step_ticks = stats.max_step_ticks.max(t - tick_mark);
            tick_mark = t;
            // fully drained (or the whole initial wave quarantined):
            // nothing live and nothing pending — `logp` may be empty on
            // the quarantined path, so check before slicing it
            if core.occupied() == 0 && queue.is_empty() && chunk.is_none() {
                break;
            }
            // ---- sample one token per occupied slot; retire finishers ---
            // streamed tokens are stamped with the lane's accumulated work:
            // the logits being sampled were paid for by everything charged
            // so far (pure observability — no engine decision reads it)
            core.clock = stats.decode_busy_ticks + stats.prefill_blocked_ticks;
            for slot in 0..geom.slots {
                let dist = &logp[slot * geom.vocab..(slot + 1) * geom.vocab];
                if let Some(done) = core.sample(self, slot, dist) {
                    // per-sequence release: THE difference from the static
                    // engine — the KV reservation frees now, not when the
                    // whole batch drains
                    sched.release_seq(kv, seq_id_base + done.pos as u64)?;
                    results[done.pos] = Some(done.gen);
                }
            }

            // ---- slot recycling: refill freed slots from the queue ------
            if self.prefill_chunk_tokens > 0 {
                // token-budgeted step packing: each engine step carries the
                // decode batch plus at most ONE chunk of the scheduler's
                // cheapest pending prompt, sized to the budget's leftover
                // (floored at 1 so a saturated batch still progresses).
                // Only when the final chunk lands does the task join the
                // decode batch — token-identically, since the completed
                // cache and logits row match a monolithic `prefill_slot`
                // bit-for-bit and per-token sampling is task-keyed.
                if chunk.is_none() {
                    if let Some(slot) = core.free_slot() {
                        if let Some(pos) =
                            admit_next(sched, kv, &mut queue, tasks, seq_id_base)
                        {
                            chunk = Some(ChunkInProgress { pos, slot, offset: 0 });
                            snap_residency(kv, &mut stats);
                        }
                    }
                }
                if let Some(c) = chunk.as_mut() {
                    let (idx, task) = tasks[c.pos];
                    match prefill_chunk_step(
                        b,
                        &geom,
                        c,
                        &task.prompt_ids,
                        self.prefill_chunk_tokens,
                        core.occupied(),
                        self.fault_retries,
                        &mut stats,
                    ) {
                        Ok((Some(row), _)) => {
                            // final chunk: the slot's cache now equals a
                            // monolithic prefill — join the decode batch
                            stats.refills += 1;
                            let (pos, slot) = (c.pos, c.slot);
                            chunk = None;
                            if let Some(done) =
                                core.join(self, slot, pos, idx, &task.prompt_ids, &row, seed)
                            {
                                // degenerate single-token sequence
                                sched.release_seq(kv, seq_id_base + done.pos as u64)?;
                                results[done.pos] = Some(done.gen);
                            }
                        }
                        Ok((None, _)) => {} // mid-prompt: resume next step
                        Err(e) if self.fault_policy.is_quarantine() => {
                            let _ = e;
                            sched.quarantine_seq(kv, seq_id_base + c.pos as u64)?;
                            stats.failed_tasks += 1;
                            results[c.pos] =
                                Some(GenSeq::failed_seq(idx, task.prompt_ids.clone()));
                            chunk = None;
                        }
                        Err(e) => return Err(e),
                    }
                }
            } else {
            for slot in 0..geom.slots {
                if core.slots[slot].is_some() {
                    continue;
                }
                // `admit_next` refusal means the memory wall (retry after
                // future releases) or an empty queue — either way stop
                while let Some(pos) =
                    admit_next(sched, kv, &mut queue, tasks, seq_id_base)
                {
                    let (idx, task) = tasks[pos];
                    let (row, attached) =
                        match pcache.slot_prefill(b, slot, &task.prompt_ids, &mut stats) {
                            Ok(ra) => ra,
                            Err(e) if self.fault_policy.is_quarantine() => {
                                // per-task fault: only THIS admission is
                                // poisoned — release it, record the failure,
                                // and try the next pending task for the slot
                                let _ = e;
                                sched.quarantine_seq(kv, seq_id_base + pos as u64)?;
                                stats.failed_tasks += 1;
                                results[pos] =
                                    Some(GenSeq::failed_seq(idx, task.prompt_ids.clone()));
                                continue;
                            }
                            Err(e) => return Err(e),
                        };
                    stats.refills += 1;
                    // serial engine: the whole decode batch stalls for this
                    // slot prefill — the bubble the pipelined lane removes.
                    // A shared attach is a slot write, not a model run, so
                    // it stalls for attach_ticks only.
                    stats.prefill_blocked_ticks += if attached {
                        geom.costs.attach_ticks
                    } else {
                        geom.costs.slot_prefill_ticks
                    };
                    snap_residency(kv, &mut stats);
                    if let Some(done) = core.join(self, slot, pos, idx, &task.prompt_ids, &row, seed)
                    {
                        // degenerate single-token sequence: release and try
                        // the next pending prompt for this same slot
                        sched.release_seq(kv, seq_id_base + done.pos as u64)?;
                        results[done.pos] = Some(done.gen);
                        continue;
                    }
                    break;
                }
            }
            }

            // ---- drained? -----------------------------------------------
            if core.occupied() == 0 {
                if chunk.is_some() {
                    // the in-flight chunk is the only live work: keep
                    // advancing it (it charges ticks every pass, so the
                    // virtual clock moves and this cannot spin forever)
                    continue;
                }
                if queue.is_empty() {
                    break;
                }
                bail!(
                    "continuous rollout stalled: {} pending but nothing \
                     admissible (reserve {} > free KV {})",
                    queue.len(),
                    sched.reserve_per_seq,
                    kv.available()
                );
            }

            // ---- compression trigger (the shared per-sequence rule); the
            // freed residency returns to the pool immediately under paged
            // admission (no-op worst-case). A sequence still attached to a
            // shared prefix forks copy-on-write instead — which can stall
            // at the wall and preempt, exactly like growth ----------------
            let compressed = match core.compress_step(b, &mut stats) {
                Ok(c) => c,
                Err(e) if self.fault_policy.is_quarantine() => {
                    // batch fault: every live member shared the failed call
                    let _ = e;
                    for live in core.quarantine_live(sched, kv, seq_id_base, &mut stats)? {
                        results[live.pos] = Some(live.gen);
                    }
                    continue; // refill from the queue on the next pass
                }
                Err(e) => return Err(e),
            };
            for (_slot, v) in
                core.compress_finish(sched, kv, seq_id_base, &compressed, &mut stats)?
            {
                queue.push_front(v.pos);
            }

            // ---- paged growth; stalls preempt the lowest-progress
            // sequence and requeue it (rerun is token-identical) ----------
            for (_slot, v) in core.grow_step(sched, kv, seq_id_base, &mut stats)? {
                queue.push_front(v.pos);
            }

            // ---- one decode step over the mixed batch -------------------
            // (the deadlock guard above guarantees growth leaves at least
            // one survivor on a single lane)
            logp = match core.decode_step(b, &mut stats) {
                Ok(l) => l,
                Err(e) if self.fault_policy.is_quarantine() => {
                    let _ = e;
                    for live in core.quarantine_live(sched, kv, seq_id_base, &mut stats)? {
                        results[live.pos] = Some(live.gen);
                    }
                    continue; // stale logits sample over empty slots: no-op
                }
                Err(e) => return Err(e),
            };
        }

        // fold the final iteration's charges into the per-step high-water
        let t = stats.decode_busy_ticks + stats.prefill_blocked_ticks;
        stats.max_step_ticks = stats.max_step_ticks.max(t - tick_mark);

        // serial engine: makespan is the sum of everything the lane did
        stats.modeled_makespan_ticks =
            stats.decode_busy_ticks + stats.prefill_blocked_ticks + stats.sched_stall_ticks;
        let out: Vec<GenSeq> = results
            .into_iter()
            .map(|s| s.expect("every queued task completed"))
            .collect();
        Ok((out, stats))
    }
}
