//! Training metrics: named time-series with CSV persistence.
//!
//! Every figure in the paper's §5.3 (reward, response length, entropy,
//! mismatch KL, rejection rate, clip ratio, grad norm) is a column here;
//! the figure harnesses replay the CSVs. The rollout-engine columns
//! (`decode_steps`, `slot_occupancy`, `refills`, `preemptions`,
//! `rollout_workers`, and the modeled-time breakdown
//! `decode_busy_ticks` / `prefill_blocked_ticks` / `sched_stall_ticks` /
//! `modeled_makespan_ticks`) share one denominator convention — device
//! work, never engine loop iterations — so static/continuous/pipelined
//! runs are comparable column-for-column (see `RolloutStats`).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Column-oriented step metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// step -> (name -> value)
    rows: Vec<BTreeMap<String, f64>>,
    names: Vec<String>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a new step row.
    pub fn begin_step(&mut self) {
        self.rows.push(BTreeMap::new());
    }

    /// Record a value for the current step.
    pub fn push(&mut self, name: &str, value: f64) {
        if self.rows.is_empty() {
            self.begin_step();
        }
        if !self.names.iter().any(|n| n == name) {
            self.names.push(name.to_string());
        }
        self.rows.last_mut().unwrap().insert(name.to_string(), value);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Series for one metric (NaN where missing).
    pub fn series(&self, name: &str) -> Vec<f64> {
        self.rows
            .iter()
            .map(|r| r.get(name).copied().unwrap_or(f64::NAN))
            .collect()
    }

    /// Last value of a metric.
    pub fn last(&self, name: &str) -> Option<f64> {
        self.rows.iter().rev().find_map(|r| r.get(name)).copied()
    }

    /// Mean of the final `k` values of a metric (collapse detection etc.).
    pub fn tail_mean(&self, name: &str, k: usize) -> f64 {
        let s: Vec<f64> = self
            .series(name)
            .into_iter()
            .filter(|v| !v.is_nan())
            .collect();
        if s.is_empty() {
            return f64::NAN;
        }
        let tail = &s[s.len().saturating_sub(k)..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    /// The CSV header line this metrics object would write.
    fn header(&self) -> String {
        let mut h = String::from("step");
        for n in &self.names {
            h.push(',');
            h.push_str(n);
        }
        h
    }

    /// Write all series as CSV (step column first). A fresh file gets
    /// the header; overwriting an EXISTING csv whose header doesn't
    /// match this run's schema is an ERROR, not a silent replace —
    /// metrics columns grow across versions (engine counters, fleet
    /// counters, ...) and the figure harnesses replay old CSVs, so
    /// schema drift must surface at write time instead of corrupting a
    /// trajectory two tools downstream. The mismatching file is left
    /// untouched; move it aside or pick a fresh out-dir.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let header = self.header();
        if let Ok(existing) = std::fs::read_to_string(path) {
            if let Some(old) = existing.lines().next() {
                if old != header {
                    bail!(
                        "refusing to overwrite {}: existing header\n  {}\n\
                         does not match this run's schema\n  {}\n\
                         (metrics schema drift — move the old csv aside or \
                         write to a fresh out-dir)",
                        path.display(),
                        old,
                        header
                    );
                }
            }
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(f, "{header}")?;
        for (i, row) in self.rows.iter().enumerate() {
            write!(f, "{i}")?;
            for n in &self.names {
                match row.get(n) {
                    Some(v) => write!(f, ",{v}")?,
                    None => write!(f, ",")?,
                }
            }
            writeln!(f)?;
        }
        Ok(())
    }

    /// Read a CSV previously written by `write_csv` (figure harnesses
    /// reuse earlier runs instead of re-training).
    pub fn read_csv(path: &Path) -> Result<Metrics> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut lines = text.lines();
        let header = lines.next().context("empty csv")?;
        let names: Vec<String> = header.split(',').skip(1).map(str::to_string).collect();
        let mut m = Metrics { rows: vec![], names: names.clone() };
        for line in lines {
            let mut row = BTreeMap::new();
            for (name, cell) in names.iter().zip(line.split(',').skip(1)) {
                if let Ok(v) = cell.parse::<f64>() {
                    row.insert(name.clone(), v);
                }
            }
            m.rows.push(row);
        }
        Ok(m)
    }

    /// One-line human summary of the current step.
    pub fn step_summary(&self, keys: &[&str]) -> String {
        let row = match self.rows.last() {
            Some(r) => r,
            None => return String::new(),
        };
        let mut parts = vec![format!("step {:>4}", self.rows.len() - 1)];
        for k in keys {
            if let Some(v) = row.get(*k) {
                parts.push(format!("{k}={v:.4}"));
            }
        }
        parts.join("  ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_and_tail() {
        let mut m = Metrics::new();
        for i in 0..5 {
            m.begin_step();
            m.push("reward", i as f64);
            if i % 2 == 0 {
                m.push("kl", 0.1 * i as f64);
            }
        }
        assert_eq!(m.series("reward"), vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.last("kl"), Some(0.4));
        assert!((m.tail_mean("reward", 2) - 3.5).abs() < 1e-9);
        let kl = m.series("kl");
        assert!(kl[1].is_nan());
    }

    #[test]
    fn csv_read_roundtrip() {
        let mut m = Metrics::new();
        for i in 0..4 {
            m.begin_step();
            m.push("reward", i as f64 * 0.25);
            if i % 2 == 0 {
                m.push("kl", 1e-3 * i as f64);
            }
        }
        let dir = std::env::temp_dir().join("srl_metrics_test");
        let p = dir.join("rt.csv");
        std::fs::remove_file(&p).ok(); // stale schemas persist across runs
        m.write_csv(&p).unwrap();
        let m2 = Metrics::read_csv(&p).unwrap();
        assert_eq!(m2.len(), 4);
        assert_eq!(m2.series("reward"), m.series("reward"));
        assert_eq!(m2.last("kl"), m.last("kl"));
        // missing cells stay missing
        assert!(m2.series("kl")[1].is_nan());
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut m = Metrics::new();
        m.begin_step();
        m.push("a", 1.0);
        m.begin_step();
        m.push("a", 2.0);
        m.push("b", 3.0);
        let dir = std::env::temp_dir().join("srl_metrics_test");
        let p = dir.join("m.csv");
        std::fs::remove_file(&p).ok(); // stale schemas persist across runs
        m.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "step,a,b");
        assert_eq!(lines[1], "0,1,");
        assert_eq!(lines[2], "1,2,3");
    }

    #[test]
    fn csv_header_mismatch_is_an_error_and_preserves_the_file() {
        let dir = std::env::temp_dir().join("srl_metrics_test");
        let p = dir.join("drift.csv");
        // the temp dir persists across test runs: start from a known file
        std::fs::remove_file(&p).ok();
        let mut old = Metrics::new();
        old.begin_step();
        old.push("reward", 1.0);
        old.write_csv(&p).unwrap();
        // a newer build grows the schema — overwriting must fail loudly
        let mut new = Metrics::new();
        new.begin_step();
        new.push("reward", 2.0);
        new.push("replica_steals", 0.0);
        let err = new.write_csv(&p).unwrap_err().to_string();
        assert!(err.contains("schema"), "unhelpful error: {err}");
        // ... and leave the existing trajectory untouched
        let kept = Metrics::read_csv(&p).unwrap();
        assert_eq!(kept.series("reward"), vec![1.0]);
        // a matching schema still rewrites in place (checkpoint refresh)
        old.begin_step();
        old.push("reward", 3.0);
        old.write_csv(&p).unwrap();
        assert_eq!(Metrics::read_csv(&p).unwrap().len(), 2);
    }
}
