//! The replica tier: a data-parallel rollout fleet.
//!
//! Every layer below this one — `Scheduler`, `KvMemoryManager`, the
//! engine shells — manages ONE engine behind one KV wall. A `Replica`
//! bundles a full instance of that stack (scheduler + private memory
//! wall + backend lane pool), and `rollout_fleet` drives N of them as a
//! unit:
//!
//! * a **global router** assigns each task to the least-loaded replica,
//!   where load is the *modeled* cost of the work already routed there —
//!   predicted residency × admission cost (the same virtual-clock oracle
//!   the schedulers use) — not queue length, so one giant prompt counts
//!   for what it will actually occupy;
//! * each replica drains its queue on its own thread with whichever
//!   engine shell the config selects (static / continuous / pipelined —
//!   the pipelined shell still runs its own worker lanes *inside* the
//!   replica);
//! * with `replica-steal = on`, a drained replica robs the highest-load
//!   not-yet-admitted task from the most-loaded peer (cost-weighted
//!   victim selection, lifting the per-lane steal heuristic across
//!   replica boundaries). Stolen tasks were never admitted to the
//!   victim's scheduler — they sit in the fleet queue — so each
//!   replica's pool conservation invariants hold untouched; the thief
//!   admits against its own wall.
//!
//! Determinism stays the load-bearing invariant: per-task RNG
//! (`task_rng`) keys sampling on the (rollout seed, task index) pair the
//! caller supplies, so tokens are identical for any replica count, any
//! routing, and any steal schedule — `tests/engine_equivalence.rs`
//! extends its propcheck grid with a `{replicas 1, 2, 4}` axis to prove
//! it. With stealing OFF the fleet is fully deterministic (each replica
//! runs exactly one engine pass over its routed queue), which is what
//! the fleet bench part records; with stealing ON, batch composition
//! depends on thread timing, so only tokens — not tick stats — are
//! reproducible.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::EngineKind;
use crate::data::task::Task;

use super::backend::RolloutBackend;
use super::engine::core::panic_msg;
use super::engine::{GenSeq, RolloutCtx, RolloutPolicy, RolloutStats, StreamHub};
use super::kv_manager::KvMemoryManager;
use super::scheduler::Scheduler;

/// One member of the rollout fleet: a full engine instance. `backends`
/// is the replica's lane pool — the single-lane engines use
/// `backends[0]`; the pipelined engine uses every lane, with the LAST
/// one acting as the dedicated prefill-executor lane when the policy
/// runs `prefill = async` and at least two lanes exist (the same
/// convention the eval harness uses).
pub struct Replica<B: RolloutBackend> {
    pub sched: Scheduler,
    pub kv: KvMemoryManager,
    pub backends: Vec<B>,
}

impl<B: RolloutBackend> Replica<B> {
    pub fn new(sched: Scheduler, kv: KvMemoryManager, backends: Vec<B>) -> Self {
        Replica { sched, kv, backends }
    }
}

/// What the fleet did, for tests, benches, and metrics: the routing
/// decision per task, the router's modeled per-replica load, each
/// replica's own (serially merged) stats, and how many cross-replica
/// steals happened.
#[derive(Debug, Clone, Default)]
pub struct FleetReport {
    pub replicas: usize,
    /// `routed[i]` = replica index task `i` (input-slice order) was
    /// routed to by the load model (before any stealing).
    pub routed: Vec<usize>,
    /// Router's total modeled load per replica after routing.
    pub modeled_load: Vec<u64>,
    /// Per-replica rollout stats (serial merge of that replica's runs).
    pub per_replica: Vec<RolloutStats>,
    /// Tasks that actually moved across replica boundaries.
    pub replica_steals: usize,
    /// Tasks requeued from a dead replica to a survivor (`fault-policy =
    /// quarantine`); reruns are token-identical by per-task RNG.
    pub requeues: usize,
    /// Replicas whose engine pass failed (returned error or panicked) and
    /// were retired from the fleet, their work requeued to survivors.
    pub replica_deaths: usize,
}

/// The modeled cost of one task on one replica: predicted residency ×
/// admission cost. Residency is how much of the wall the task occupies
/// while live; admission cost is the unclamped prompt+response length —
/// a ready-time proxy. The product is the "area" the task sweeps
/// through the replica's memory wall over time, which is the quantity
/// a makespan-aware router should balance (two short prompts and one
/// long one are NOT the same load even when the queue lengths match).
fn task_load(sched: &Scheduler, task: &Task, max_response: usize) -> u64 {
    let prompt = task.prompt_ids.len();
    let residency = sched.predicted_residency(prompt, max_response) as u64;
    let cost = sched.admission_cost(prompt, max_response) as u64;
    residency * cost
}

/// Greedy least-loaded routing: tasks are considered in input order and
/// each goes to the replica with the smallest accumulated modeled load
/// (stable tie-break: lowest replica index). Returns the assignment per
/// task, the per-task modeled load (under its assigned replica's
/// scheduler), and the final per-replica totals. Deterministic — pure
/// arithmetic over the task list.
pub fn route_tasks<B: RolloutBackend>(
    replicas: &[Replica<B>],
    tasks: &[(usize, &Task)],
    max_response: usize,
) -> (Vec<usize>, Vec<u64>, Vec<u64>) {
    let n_reps = replicas.len();
    let mut load = vec![0u64; n_reps];
    let mut routed = Vec::with_capacity(tasks.len());
    let mut per_task = Vec::with_capacity(tasks.len());
    for (_, task) in tasks {
        // least-loaded first; min_by_key keeps the FIRST minimum, so
        // ties stably break to the lowest replica index
        let pick = (0..n_reps).min_by_key(|&r| load[r]).unwrap_or(0);
        let cost = task_load(&replicas[pick].sched, task, max_response);
        load[pick] += cost;
        routed.push(pick);
        per_task.push(cost);
    }
    (routed, per_task, load)
}

/// Shared fleet state the replica threads coordinate through. Queues
/// hold input-slice positions (not `Task`s) so a steal moves only an
/// index; `pending_load` mirrors the modeled load still queued per
/// replica so victim selection stays cost-weighted as queues drain.
struct FleetShared {
    queues: Vec<VecDeque<usize>>,
    pending_load: Vec<u64>,
    results: Vec<Option<GenSeq>>,
    per_replica: Vec<RolloutStats>,
    steals: usize,
    /// Which replicas are still serving (`fault-policy = quarantine`
    /// failover: a dead replica flips its flag, requeues its work, and
    /// exits; its pool is never reused).
    alive: Vec<bool>,
    /// Tasks not yet delivered to `results`. Failover parks drained
    /// replicas on the condvar until this hits zero — a dying peer may
    /// still requeue work into their queues.
    outstanding: usize,
    deaths: usize,
    requeues: usize,
    failed: Option<String>,
}

/// Run one batch of tasks on one replica with the configured engine
/// shell. `base` namespaces sequence ids within the replica's own KV
/// wall (walls are private, so bases only need to be distinct across a
/// single replica's successive runs). `stream`, when a serving front-end
/// subscribed one, is cloned into the engine context — the hub is shared
/// (`Arc`), so every replica emits into the same per-request sinks.
fn run_batch<B: RolloutBackend + Send>(
    policy: &RolloutPolicy,
    engine: EngineKind,
    rep: &mut Replica<B>,
    batch: &[(usize, &Task)],
    seed: u64,
    base: u64,
    stream: &Option<StreamHub>,
) -> Result<(Vec<GenSeq>, RolloutStats)> {
    let Replica { sched, kv, backends } = rep;
    let ctx = RolloutCtx { sched, kv, seq_id_base: base, stream: stream.clone() };
    match engine {
        EngineKind::Static => {
            policy.rollout_static_queue(&mut backends[0], batch, seed, ctx)
        }
        EngineKind::Continuous => {
            policy.rollout_continuous(&mut backends[0], batch, seed, ctx)
        }
        EngineKind::Pipelined => {
            if policy.prefill.is_async() && backends.len() >= 2 {
                let split = backends.len() - 1;
                let (lanes, exec) = backends.split_at_mut(split);
                policy.rollout_pipelined(lanes, Some(&mut exec[0]), batch, seed, ctx)
            } else {
                policy.rollout_pipelined(backends, None, batch, seed, ctx)
            }
        }
    }
}

/// Roll out `tasks` across a fleet of replicas. Results come back in
/// input-slice order; the fleet-level `RolloutStats` is the PARALLEL
/// composition (`merge_parallel`) of the per-replica stats — makespan
/// is the slowest replica, lanes sum — and `FleetReport` carries the
/// routing/steal detail. A single-replica fleet short-circuits to one
/// direct engine pass on the calling thread (no router, no threads):
/// bit-exact with calling the engine shell yourself.
pub fn rollout_fleet<B: RolloutBackend + Send>(
    policy: &RolloutPolicy,
    engine: EngineKind,
    replicas: &mut [Replica<B>],
    tasks: &[(usize, &Task)],
    seed: u64,
    replica_steal: bool,
) -> Result<(Vec<GenSeq>, RolloutStats, FleetReport)> {
    rollout_fleet_streaming(policy, engine, replicas, tasks, seed, replica_steal, None)
}

/// [`rollout_fleet`] with a live token sink: the serving front-end's
/// entry. The hub is shared (`Arc`-cloned into every replica thread's
/// engine context), so per-request streams work across replica
/// boundaries — including stolen and failed-over tasks, whose events
/// carry the same caller-side task index wherever they run. `None` is
/// bit-exact with `rollout_fleet`.
pub fn rollout_fleet_streaming<B: RolloutBackend + Send>(
    policy: &RolloutPolicy,
    engine: EngineKind,
    replicas: &mut [Replica<B>],
    tasks: &[(usize, &Task)],
    seed: u64,
    replica_steal: bool,
    stream: Option<StreamHub>,
) -> Result<(Vec<GenSeq>, RolloutStats, FleetReport)> {
    let n_reps = replicas.len();
    if n_reps == 0 {
        bail!("rollout_fleet needs at least one replica");
    }
    for (r, rep) in replicas.iter().enumerate() {
        if rep.backends.is_empty() {
            bail!("replica {r} has no backend lanes");
        }
    }
    let n = tasks.len();
    let max_response = policy.sampling.max_response;
    let (routed, per_task_load, modeled_load) = route_tasks(replicas, tasks, max_response);

    if n_reps == 1 {
        // Single replica: the fleet tier vanishes — one engine pass,
        // calling thread, seq ids from 0. This is the `replicas = 1`
        // bit-exactness guarantee.
        let (seqs, stats) = run_batch(policy, engine, &mut replicas[0], tasks, seed, 0, &stream)?;
        let mut fleet = RolloutStats::default();
        fleet.merge_parallel(&stats);
        let report = FleetReport {
            replicas: 1,
            routed,
            modeled_load,
            per_replica: vec![stats],
            replica_steals: 0,
            requeues: 0,
            replica_deaths: 0,
        };
        return Ok((seqs, fleet, report));
    }

    let mut queues: Vec<VecDeque<usize>> = vec![VecDeque::new(); n_reps];
    for (pos, &r) in routed.iter().enumerate() {
        queues[r].push_back(pos);
    }
    let mut pending_load = vec![0u64; n_reps];
    for (pos, &r) in routed.iter().enumerate() {
        pending_load[r] += per_task_load[pos];
    }
    let shared = Mutex::new(FleetShared {
        queues,
        pending_load,
        results: (0..n).map(|_| None).collect(),
        per_replica: vec![RolloutStats::default(); n_reps],
        steals: 0,
        alive: vec![true; n_reps],
        outstanding: n,
        deaths: 0,
        requeues: 0,
        failed: None,
    });
    let cv = Condvar::new();
    // Replica failover only under `fault-policy = quarantine`: the
    // default abort policy keeps the seed behavior bit-exact (first
    // replica error fails the whole fleet, nothing waits or requeues).
    let failover = policy.fault_policy.is_quarantine();

    let stream = &stream;
    std::thread::scope(|scope| {
        for (r, rep) in replicas.iter_mut().enumerate() {
            let (shared, cv) = (&shared, &cv);
            let per_task_load = &per_task_load;
            scope.spawn(move || {
                // With stealing off each replica drains its whole queue
                // in ONE engine pass (deterministic: batch composition
                // is the router's, independent of thread timing). With
                // stealing on it takes modest chunks so tail work stays
                // visible to drained peers.
                let chunk = (rep.sched.slots * 2).max(1);
                let mut stats = RolloutStats::default();
                let mut runs = 0u64;
                'serve: loop {
                    let mut batch_pos: Vec<usize> = Vec::new();
                    {
                        let mut sh = shared.lock().unwrap();
                        loop {
                            if sh.failed.is_some() {
                                break;
                            }
                            if !sh.queues[r].is_empty() {
                                let take =
                                    if replica_steal { chunk } else { sh.queues[r].len() };
                                for _ in 0..take.min(sh.queues[r].len()) {
                                    let pos = sh.queues[r].pop_front().unwrap();
                                    sh.pending_load[r] =
                                        sh.pending_load[r].saturating_sub(per_task_load[pos]);
                                    batch_pos.push(pos);
                                }
                                break;
                            }
                            if replica_steal {
                                // Drained: rob the most-loaded peer of its
                                // single highest-load queued task. Both picks
                                // are cost-weighted (modeled load, not queue
                                // length), stable ties to the lowest index /
                                // earliest queue position.
                                let victim = (0..sh.queues.len())
                                    .filter(|&v| v != r && !sh.queues[v].is_empty())
                                    .max_by_key(|&v| {
                                        (sh.pending_load[v], std::cmp::Reverse(v))
                                    });
                                if let Some(v) = victim {
                                    let at = sh.queues[v]
                                        .iter()
                                        .enumerate()
                                        .max_by_key(|&(i, &pos)| {
                                            (per_task_load[pos], std::cmp::Reverse(i))
                                        })
                                        .map(|(i, _)| i)
                                        .unwrap();
                                    let pos = sh.queues[v].remove(at).unwrap();
                                    sh.pending_load[v] =
                                        sh.pending_load[v].saturating_sub(per_task_load[pos]);
                                    sh.steals += 1;
                                    batch_pos.push(pos);
                                    break;
                                }
                            }
                            // Own queue empty, nothing stealable. Without
                            // failover that means done (the seed behavior).
                            // With failover a dying peer may yet requeue
                            // work here, so park until every task is
                            // delivered (or something fails).
                            if !failover || sh.outstanding == 0 {
                                break;
                            }
                            let (g, _) =
                                cv.wait_timeout(sh, Duration::from_millis(2)).unwrap();
                            sh = g;
                        }
                    }
                    if batch_pos.is_empty() {
                        break;
                    }
                    let batch: Vec<(usize, &Task)> =
                        batch_pos.iter().map(|&p| tasks[p]).collect();
                    // seq ids: private wall, so runs of THIS replica just
                    // need disjoint id ranges; spacing by the global task
                    // count over-provisions safely.
                    let base = runs * n as u64;
                    runs += 1;
                    // A panicking engine pass (e.g. an injected backend
                    // panic past the retry budget) is caught here so the
                    // replica can die IN BAND: flag itself dead, requeue
                    // its work, and let survivors finish the step.
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || run_batch(policy, engine, rep, &batch, seed, base, stream),
                    ));
                    let note = match outcome {
                        Ok(Ok((seqs, rstats))) => {
                            stats.merge(&rstats);
                            let mut sh = shared.lock().unwrap();
                            for (&pos, seq) in batch_pos.iter().zip(seqs) {
                                sh.results[pos] = Some(seq);
                            }
                            sh.outstanding -= batch_pos.len();
                            drop(sh);
                            cv.notify_all();
                            continue 'serve;
                        }
                        Ok(Err(e)) => format!("{e:#}"),
                        Err(payload) => format!("panicked: {}", panic_msg(&*payload)),
                    };
                    // ---- this replica is dead -----------------------------
                    let mut sh = shared.lock().unwrap();
                    if !failover {
                        sh.failed.get_or_insert(format!("replica {r}: {note}"));
                    } else {
                        sh.alive[r] = false;
                        sh.deaths += 1;
                        // requeue the in-flight batch plus everything still
                        // queued here to the least-loaded survivors; reruns
                        // are token-identical by per-task RNG. The dead
                        // replica's pool is never reused (its wall may hold
                        // stranded reservations), so conservation claims
                        // apply to survivors only.
                        let mut orphans = batch_pos.clone();
                        orphans.extend(sh.queues[r].drain(..));
                        sh.pending_load[r] = 0;
                        let survivors: Vec<usize> =
                            (0..n_reps).filter(|&t| sh.alive[t]).collect();
                        if survivors.is_empty() {
                            sh.failed.get_or_insert(format!(
                                "replica {r} died with no survivors to adopt its {} tasks: \
                                 {note}",
                                orphans.len()
                            ));
                        } else {
                            for pos in orphans {
                                let &tgt = survivors
                                    .iter()
                                    .min_by_key(|&&t| sh.pending_load[t])
                                    .unwrap();
                                sh.queues[tgt].push_back(pos);
                                sh.pending_load[tgt] += per_task_load[pos];
                                sh.requeues += 1;
                            }
                        }
                    }
                    drop(sh);
                    cv.notify_all();
                    break;
                }
                let mut sh = shared.lock().unwrap();
                sh.per_replica[r] = stats;
                drop(sh);
                // a replica exiting for any reason must wake parked peers
                // so they re-check the drain predicate
                cv.notify_all();
            });
        }
    });

    let sh = shared.into_inner().unwrap();
    if let Some(msg) = sh.failed {
        bail!("fleet rollout failed: {msg}");
    }
    let mut fleet = RolloutStats::default();
    for rstats in &sh.per_replica {
        fleet.merge_parallel(rstats);
    }
    // fleet-level fault counters live in the shared state, not in any
    // replica's own stats (a dead replica cannot report its own death)
    fleet.requeues += sh.requeues;
    fleet.replica_deaths += sh.deaths;
    let mut out = Vec::with_capacity(n);
    for (pos, seq) in sh.results.into_iter().enumerate() {
        match seq {
            Some(s) => out.push(s),
            None => bail!("fleet rollout lost task at position {pos}"),
        }
    }
    let report = FleetReport {
        replicas: n_reps,
        routed,
        modeled_load,
        per_replica: sh.per_replica,
        replica_steals: sh.steals,
        requeues: sh.requeues,
        replica_deaths: sh.deaths,
    };
    Ok((out, fleet, report))
}
