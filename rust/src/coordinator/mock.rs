//! Deterministic in-process model backend — the heart of the
//! determinism/equivalence test harness.
//!
//! `MockModelBackend` implements `RolloutBackend` with a pure-Rust "model"
//! whose log-probs are a deterministic hash of the slot's own retained
//! cache contents. That gives it exactly the properties the engine
//! equivalence tests need, with no artifacts and no PJRT runtime:
//!
//! * **Batch-row independence** — a slot's logits depend only on its own
//!   cache, so recycling neighbour slots cannot perturb a sequence. Any
//!   cross-slot leak in an engine implementation breaks token equality.
//! * **Exact `prefill_slot` = batched-prefill row** — both write the same
//!   per-slot cache, so static and continuous engines must agree
//!   bit-for-bit on tokens and `sampler_logp`.
//! * **Compression-sensitivity** — logits hash the retained tokens at
//!   their retained positions, so sparse eviction changes the sampling
//!   distribution (as real compression does) while staying deterministic.
//! * **Bounds enforcement** — any cache write at or past `capacity` is an
//!   error, so an engine that misses a compression trigger fails loudly.
//!
//! Response lengths vary task-to-task (an EOS pull grows with resident
//! length plus content hash), producing the skewed long-tail length
//! distributions the continuous engine exists to exploit.
//!
//! Freed slots — finished *or preempted* (paged admission) — keep their
//! stale cache until the next `prefill_slot` overwrites it; the dead PAD
//! writes the decode loop feeds them land in that stale cache (or drop as
//! OOB), exactly like the artifacts' scatter. Content determinism is what
//! makes a preempted-and-requeued task regenerate bit-identical tokens.

use anyhow::{bail, Result};

use crate::data::tokenizer::{BOS, EOS, PAD};
use crate::util::rng::Rng;

use super::backend::{CostModel, RolloutBackend};

/// Which backend call a [`FaultPlan`] entry targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultOp {
    Prefill,
    PrefillSlot,
    PreparePrefill,
    ApplyPrefill,
    Decode,
    Compress,
    PrefillChunk,
}

impl FaultOp {
    const COUNT: usize = 7;

    fn index(self) -> usize {
        match self {
            FaultOp::Prefill => 0,
            FaultOp::PrefillSlot => 1,
            FaultOp::PreparePrefill => 2,
            FaultOp::ApplyPrefill => 3,
            FaultOp::Decode => 4,
            FaultOp::Compress => 5,
            FaultOp::PrefillChunk => 6,
        }
    }

    /// Stable name used in injected error/panic messages (tests match on it).
    pub fn label(self) -> &'static str {
        match self {
            FaultOp::Prefill => "prefill",
            FaultOp::PrefillSlot => "prefill_slot",
            FaultOp::PreparePrefill => "prepare_prefill",
            FaultOp::ApplyPrefill => "apply_prefill",
            FaultOp::Decode => "decode",
            FaultOp::Compress => "compress",
            FaultOp::PrefillChunk => "prefill_chunk",
        }
    }
}

/// What an injected fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The backend call returns an `Err` (transient fault: retryable).
    Err,
    /// The backend call panics with a distinctive payload string
    /// (crash fault: kills the calling worker/replica thread).
    Panic,
}

/// Deterministic, seeded fault plan for [`MockModelBackend`].
///
/// Faults fire at the TOP of a backend call, before any cache mutation
/// or validation, so a failed call has zero side effects and a retry
/// re-executes it bit-identically. Two addressing modes compose:
///
/// * **Scripted by call count** — `(op, zero-based per-op call index)`
///   entries. Note the failing call still advances the op's counter, so
///   a burst of K consecutive faults is entries at indices `i..i+K`.
/// * **Scripted by task** — a prompt-keyed entry fires every time a
///   per-task prefill op (`prefill_slot` / `prepare_prefill`) is called
///   with exactly that prompt, which pins a fault to one task no matter
///   where scheduling places it.
/// * **Probabilistic** — a seeded per-call error rate (`Rng::chance`);
///   the stream is a pure function of the plan seed and the call
///   sequence, so reruns replay the same faults.
///
/// The plan travels with the backend through `Clone`, counters and all:
/// each engine lane / replica clone counts its own calls independently,
/// which is what makes per-lane fault schedules deterministic.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    scripted: Vec<(FaultOp, u64, FaultKind)>,
    prompt_faults: Vec<(Vec<i32>, FaultKind)>,
    error_rate: f64,
    rng: Option<Rng>,
    calls: [u64; FaultOp::COUNT],
    /// Total injected `Err` faults fired so far (tests check exactness).
    pub injected_errs: u64,
}

impl FaultPlan {
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// Script a fault at the `call`-th (zero-based) invocation of `op`.
    pub fn scripted(mut self, op: FaultOp, call: u64, kind: FaultKind) -> Self {
        self.scripted.push((op, call, kind));
        self
    }

    /// Script a fault that fires on EVERY `prefill_slot` /
    /// `prepare_prefill` call carrying exactly this prompt — a
    /// task-keyed fault (a task's prompt is its identity to the
    /// backend), independent of slot placement or admission order.
    pub fn scripted_prompt(mut self, prompt: Vec<i32>, kind: FaultKind) -> Self {
        self.prompt_faults.push((prompt, kind));
        self
    }

    /// Add a seeded probabilistic `Err` fault: each call fails with
    /// probability `rate`, drawn from a private deterministic stream.
    pub fn with_error_rate(mut self, rate: f64, seed: u64) -> Self {
        self.error_rate = rate;
        self.rng = Some(Rng::new(seed));
        self
    }

    /// Calls seen so far for `op` (on THIS clone of the plan).
    pub fn calls(&self, op: FaultOp) -> u64 {
        self.calls[op.index()]
    }

    fn fire(&mut self, op: FaultOp, prompt: Option<&[i32]>) -> Result<()> {
        let idx = self.calls[op.index()];
        self.calls[op.index()] += 1;
        let mut kind = self
            .scripted
            .iter()
            .find(|&&(o, c, _)| o == op && c == idx)
            .map(|&(_, _, k)| k);
        if kind.is_none() {
            if let Some(p) = prompt {
                kind = self
                    .prompt_faults
                    .iter()
                    .find(|(fp, _)| fp == p)
                    .map(|&(_, k)| k);
            }
        }
        if kind.is_none() && self.error_rate > 0.0 {
            if let Some(rng) = &mut self.rng {
                if rng.chance(self.error_rate) {
                    kind = Some(FaultKind::Err);
                }
            }
        }
        match kind {
            Some(FaultKind::Err) => {
                self.injected_errs += 1;
                bail!("injected fault: {} call {idx} failed", op.label())
            }
            Some(FaultKind::Panic) => {
                panic!("injected fault: {} call {idx} panicked", op.label())
            }
            None => Ok(()),
        }
    }
}

/// Pure-Rust deterministic model backend (see module docs).
#[derive(Debug, Clone)]
pub struct MockModelBackend {
    slots: usize,
    prompt_len: usize,
    max_seq: usize,
    vocab: usize,
    capacity: usize,
    budget: usize,
    sparse: bool,
    /// StreamingLLM-style compression: retained prefix ("sinks") size.
    pub sinks: usize,
    /// How strongly EOS is favored as resident length grows (controls the
    /// response-length distribution's skew).
    pub eos_pull: f32,
    /// Per-slot cache: the token written at each occupied cache position.
    cache: Vec<Vec<i32>>,
    /// Writes dropped for landing at/after `capacity`. The artifacts'
    /// scatter drops out-of-bounds writes the same way; live sequences
    /// never produce them (compression fires first) — only frozen
    /// (finished) slots in the static engine do, feeding dead PAD tokens.
    pub oob_writes: u64,
    /// Deterministic per-call latency model for the virtual-clock timing
    /// harness. Zero (the default) keeps all modeled times at 0, so
    /// pre-existing stats comparisons are untouched; the pipeline benches
    /// and tests set `CostModel::representative()`.
    pub costs: CostModel,
    /// Seeded fault-injection plan (None = no faults, bit-exact seed
    /// behavior). Consulted at the top of every backend call.
    pub faults: Option<FaultPlan>,
}

impl MockModelBackend {
    /// `capacity` is the per-sequence cache bound for the chosen path:
    /// dense engines pass `max_seq` (and `budget == capacity`), sparse
    /// ones pass `budget + buffer`.
    pub fn new(
        slots: usize,
        prompt_len: usize,
        max_seq: usize,
        vocab: usize,
        capacity: usize,
        budget: usize,
        sparse: bool,
    ) -> Self {
        assert!(vocab > EOS as usize, "vocab must contain the special tokens");
        assert!(capacity >= prompt_len, "cache must fit a full prompt");
        assert!(budget <= capacity);
        MockModelBackend {
            slots,
            prompt_len,
            max_seq,
            vocab,
            capacity,
            budget,
            sparse,
            sinks: 2,
            eos_pull: 0.25,
            cache: vec![Vec::new(); slots],
            oob_writes: 0,
            costs: CostModel::default(),
            faults: None,
        }
    }

    /// Attach a latency cost model (builder style).
    pub fn with_costs(mut self, costs: CostModel) -> Self {
        self.costs = costs;
        self
    }

    /// Attach a fault-injection plan (builder style).
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Consult the fault plan (if any) at the top of a backend call.
    fn fault(&mut self, op: FaultOp, prompt: Option<&[i32]>) -> Result<()> {
        match &mut self.faults {
            Some(plan) => plan.fire(op, prompt),
            None => Ok(()),
        }
    }

    /// Dense-path mock: cache bound = max_seq, no compression.
    pub fn dense(slots: usize, prompt_len: usize, max_seq: usize, vocab: usize) -> Self {
        Self::new(slots, prompt_len, max_seq, vocab, max_seq, max_seq, false)
    }

    /// Sparse-path mock: cache bound = budget + buffer, compression live.
    pub fn sparse(
        slots: usize,
        prompt_len: usize,
        max_seq: usize,
        vocab: usize,
        budget: usize,
        buffer: usize,
    ) -> Self {
        Self::new(slots, prompt_len, max_seq, vocab, budget + buffer, budget, true)
    }

    /// Deterministic log-softmax over the vocab from one slot's retained
    /// cache prefix. Pure function of the content — bitwise reproducible.
    fn row_logp(&self, content: &[i32]) -> Vec<f32> {
        let mut h: u64 = 0x9E37_79B9_7F4A_7C15;
        for (i, &t) in content.iter().enumerate() {
            h ^= ((t as u64).wrapping_add(1))
                .wrapping_mul(0xA24B_AED4_963E_E407)
                .rotate_left((i % 61) as u32);
            h = h.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        }
        let mut logits: Vec<f32> = (0..self.vocab)
            .map(|v| {
                let hv = (h ^ (v as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93))
                    .wrapping_mul(0x2545_F491_4F6C_DD1D);
                // uniform in [-3, 3)
                ((hv >> 11) as f64 / (1u64 << 53) as f64 * 6.0 - 3.0) as f32
            })
            .collect();
        // structural tokens are never generated; EOS gets likelier as the
        // resident sequence grows (skewed, but bounded, lengths)
        logits[PAD as usize] = -30.0;
        logits[BOS as usize] = -30.0;
        logits[EOS as usize] += self.eos_pull * content.len() as f32 - 3.0;
        // log-softmax
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = logits.iter().map(|&l| (l - mx).exp()).sum();
        let lz = z.ln();
        logits.iter().map(|&l| l - mx - lz).collect()
    }
}

impl RolloutBackend for MockModelBackend {
    /// The mock's prepared prefill: the prompt plus its (purely
    /// content-determined) logits row, both computable with no access to
    /// any live cache — exactly the property that lets the async executor
    /// run it on its own backend clone.
    type Prepared = (Vec<i32>, Vec<f32>);

    fn slots(&self) -> usize {
        self.slots
    }

    fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn budget(&self) -> usize {
        self.budget
    }

    fn cost_model(&self) -> CostModel {
        self.costs
    }

    fn prefill(&mut self, ids: &[i32], plens: &[i32]) -> Result<Vec<f32>> {
        self.fault(FaultOp::Prefill, None)?;
        if ids.len() != self.slots * self.prompt_len || plens.len() != self.slots {
            bail!("prefill: bad batch shape");
        }
        let mut logp = Vec::with_capacity(self.slots * self.vocab);
        for s in 0..self.slots {
            let plen = plens[s] as usize;
            if plen == 0 || plen > self.prompt_len {
                bail!("prefill: slot {s} prompt length {plen} out of range");
            }
            self.cache[s] = ids[s * self.prompt_len..s * self.prompt_len + plen].to_vec();
            logp.extend(self.row_logp(&self.cache[s]));
        }
        Ok(logp)
    }

    fn prefill_slot(&mut self, slot: usize, prompt: &[i32]) -> Result<Vec<f32>> {
        self.fault(FaultOp::PrefillSlot, Some(prompt))?;
        if slot >= self.slots {
            bail!("prefill_slot: slot {slot} out of range");
        }
        if prompt.is_empty() || prompt.len() > self.prompt_len {
            bail!("prefill_slot: prompt length {} out of range", prompt.len());
        }
        self.cache[slot] = prompt.to_vec();
        Ok(self.row_logp(&self.cache[slot]))
    }

    fn prefill_chunk(
        &mut self,
        slot: usize,
        prompt: &[i32],
        start: usize,
        chunk: usize,
    ) -> Result<Option<Vec<f32>>> {
        // prompt-keyed like prefill_slot: a task-pinned fault follows its
        // prompt onto the chunked path too
        self.fault(FaultOp::PrefillChunk, Some(prompt))?;
        if slot >= self.slots {
            bail!("prefill_chunk: slot {slot} out of range");
        }
        if prompt.is_empty() || prompt.len() > self.prompt_len {
            bail!("prefill_chunk: prompt length {} out of range", prompt.len());
        }
        if chunk == 0 || start + chunk > prompt.len() {
            bail!(
                "prefill_chunk: range [{start}, {}) exceeds the prompt ({} tokens)",
                start + chunk,
                prompt.len()
            );
        }
        if start == 0 {
            self.cache[slot].clear();
        } else if self.cache[slot].len() != start {
            bail!(
                "prefill_chunk: slot {slot} resumes at {start} but holds {} tokens",
                self.cache[slot].len()
            );
        }
        self.cache[slot].extend_from_slice(&prompt[start..start + chunk]);
        if start + chunk == prompt.len() {
            // final chunk: the slot now holds exactly what prefill_slot
            // would have written, so the logits row is bit-identical
            Ok(Some(self.row_logp(&self.cache[slot])))
        } else {
            Ok(None)
        }
    }

    fn prepare_prefill(&mut self, prompt: &[i32]) -> Result<Self::Prepared> {
        self.fault(FaultOp::PreparePrefill, Some(prompt))?;
        if prompt.is_empty() || prompt.len() > self.prompt_len {
            bail!("prepare_prefill: prompt length {} out of range", prompt.len());
        }
        Ok((prompt.to_vec(), self.row_logp(prompt)))
    }

    fn apply_prefill(&mut self, slot: usize, prepared: Self::Prepared) -> Result<Vec<f32>> {
        self.fault(FaultOp::ApplyPrefill, None)?;
        if slot >= self.slots {
            bail!("apply_prefill: slot {slot} out of range");
        }
        let (prompt, logp) = prepared;
        self.cache[slot] = prompt;
        Ok(logp)
    }

    fn decode(&mut self, lens: &[i32], pos: &[i32], tokens: &[i32]) -> Result<Vec<f32>> {
        self.fault(FaultOp::Decode, None)?;
        if lens.len() != self.slots || pos.len() != self.slots || tokens.len() != self.slots {
            bail!("decode: bad control vector length");
        }
        let mut logp = Vec::with_capacity(self.slots * self.vocab);
        for s in 0..self.slots {
            let l = lens[s] as usize;
            if l >= self.capacity {
                // out-of-bounds scatter: dropped, like the artifacts do.
                // Reachable only for frozen slots; their logits are dead.
                self.oob_writes += 1;
                logp.extend(self.row_logp(&self.cache[s]));
                continue;
            }
            match l.cmp(&self.cache[s].len()) {
                std::cmp::Ordering::Less => self.cache[s][l] = tokens[s],
                std::cmp::Ordering::Equal => self.cache[s].push(tokens[s]),
                std::cmp::Ordering::Greater => {
                    bail!("decode: slot {s} write at {l} leaves a gap (cache len {})",
                        self.cache[s].len())
                }
            }
            logp.extend(self.row_logp(&self.cache[s][..l + 1]));
        }
        Ok(logp)
    }

    fn compress(&mut self, do_mask: &[f32]) -> Result<()> {
        self.fault(FaultOp::Compress, None)?;
        if !self.sparse {
            bail!("compress called on a dense mock");
        }
        for (s, &m) in do_mask.iter().enumerate() {
            if m <= 0.0 {
                continue;
            }
            let c = &mut self.cache[s];
            if c.len() <= self.budget {
                continue; // nothing to evict
            }
            // StreamingLLM-style retention: sink prefix + recency window
            let sinks = self.sinks.min(self.budget);
            let tail = self.budget - sinks;
            let mut kept: Vec<i32> = c[..sinks].to_vec();
            kept.extend_from_slice(&c[c.len() - tail..]);
            *c = kept;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_are_deterministic_and_normalized() {
        let m = MockModelBackend::dense(2, 8, 32, 32);
        let a = m.row_logp(&[1, 5, 9]);
        let b = m.row_logp(&[1, 5, 9]);
        assert_eq!(a, b);
        let mass: f64 = a.iter().map(|&l| (l as f64).exp()).sum();
        assert!((mass - 1.0).abs() < 1e-4, "mass {mass}");
        // content-sensitive
        let c = m.row_logp(&[1, 5, 10]);
        assert_ne!(a, c);
    }

    #[test]
    fn prefill_slot_matches_batched_row() {
        let mut a = MockModelBackend::dense(3, 6, 32, 32);
        let mut b = a.clone();
        let mut ids = vec![PAD; 3 * 6];
        ids[6..10].copy_from_slice(&[1, 7, 8, 9]); // slot 1 prompt
        ids[0] = BOS;
        ids[12] = BOS;
        let mut plens = vec![1; 3];
        plens[1] = 4;
        let full = a.prefill(&ids, &plens).unwrap();
        // other-slot contents must not matter
        b.prefill(&[5i32; 18], &[6, 6, 6]).unwrap();
        let row = b.prefill_slot(1, &[1, 7, 8, 9]).unwrap();
        assert_eq!(&full[32..64], &row[..]);
    }

    #[test]
    fn prepare_apply_matches_prefill_slot() {
        // The async-prefill contract: prepare on ONE backend, apply on
        // ANOTHER, and the target slot must end up exactly as a direct
        // prefill_slot would leave it — same cache row, same logits.
        let mut executor = MockModelBackend::dense(3, 6, 32, 32);
        let mut worker = MockModelBackend::dense(3, 6, 32, 32);
        let mut reference = MockModelBackend::dense(3, 6, 32, 32);
        worker.prefill(&[5i32; 18], &[6, 6, 6]).unwrap();
        reference.prefill(&[5i32; 18], &[6, 6, 6]).unwrap();
        let prompt = [1, 7, 8, 9];
        let prepared = executor.prepare_prefill(&prompt).unwrap();
        let applied = worker.apply_prefill(2, prepared).unwrap();
        let direct = reference.prefill_slot(2, &prompt).unwrap();
        assert_eq!(applied, direct, "prepared row diverges from prefill_slot");
        assert_eq!(worker.cache[2], reference.cache[2]);
        // neighbour slots untouched
        assert_eq!(worker.cache[0], reference.cache[0]);
        // subsequent decode sees identical state
        let a = worker.decode(&[4, 6, 4], &[6, 6, 4], &[3, 3, 3]).unwrap();
        let b = reference.decode(&[4, 6, 4], &[6, 6, 4], &[3, 3, 3]).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn one_prepared_prefill_attaches_to_many_slots() {
        // The prefix-sharing contract: ONE prepared prompt payload,
        // cloned and applied to each sibling slot of a group, must leave
        // every slot exactly as its own direct prefill_slot would —
        // that's what lets the engines prefill a GRPO group's prompt once
        // and attach it G times.
        let mut worker = MockModelBackend::dense(3, 6, 32, 32);
        let mut reference = MockModelBackend::dense(3, 6, 32, 32);
        worker.prefill(&[5i32; 18], &[6, 6, 6]).unwrap();
        reference.prefill(&[5i32; 18], &[6, 6, 6]).unwrap();
        let prompt = [1, 7, 8, 9];
        let prepared = worker.prepare_prefill(&prompt).unwrap();
        for slot in 0..3 {
            let attached = worker.apply_prefill(slot, prepared.clone()).unwrap();
            let direct = reference.prefill_slot(slot, &prompt).unwrap();
            assert_eq!(attached, direct, "slot {slot} attach diverges");
            assert_eq!(worker.cache[slot], reference.cache[slot]);
        }
        // subsequent decode sees identical state on every sibling
        let a = worker.decode(&[4, 4, 4], &[4, 4, 4], &[3, 3, 3]).unwrap();
        let b = reference.decode(&[4, 4, 4], &[4, 4, 4], &[3, 3, 3]).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn chunked_prefill_matches_monolithic_bit_for_bit() {
        // the chunked-prefill contract: after the final chunk, the slot's
        // cache and returned logits row are exactly prefill_slot's
        let mut chunked = MockModelBackend::dense(3, 8, 32, 32);
        let mut mono = MockModelBackend::dense(3, 8, 32, 32);
        chunked.prefill(&[5i32; 24], &[8, 8, 8]).unwrap();
        mono.prefill(&[5i32; 24], &[8, 8, 8]).unwrap();
        let prompt = [1, 7, 8, 9, 4, 6, 2];
        assert_eq!(chunked.prefill_chunk(1, &prompt, 0, 3).unwrap(), None);
        assert_eq!(chunked.prefill_chunk(1, &prompt, 3, 2).unwrap(), None);
        let row = chunked.prefill_chunk(1, &prompt, 5, 2).unwrap().expect("final chunk");
        let direct = mono.prefill_slot(1, &prompt).unwrap();
        assert_eq!(row, direct, "final-chunk logits diverge from prefill_slot");
        assert_eq!(chunked.cache[1], mono.cache[1]);
        // neighbour slots untouched; decode sees identical state
        assert_eq!(chunked.cache[0], mono.cache[0]);
        let a = chunked.decode(&[8, 7, 8], &[8, 7, 8], &[3, 3, 3]).unwrap();
        let b = mono.decode(&[8, 7, 8], &[8, 7, 8], &[3, 3, 3]).unwrap();
        assert_eq!(a, b);
        // a whole-prompt chunk is exactly a monolithic prefill
        let one = chunked
            .prefill_chunk(2, &prompt, 0, prompt.len())
            .unwrap()
            .expect("whole prompt completes");
        assert_eq!(one, direct);
        // resuming at the wrong offset is loud, not silent corruption
        assert!(chunked.prefill_chunk(0, &prompt, 3, 2).is_err());
        // an over-long range is rejected
        assert!(chunked.prefill_chunk(0, &prompt, 0, prompt.len() + 1).is_err());
    }

    #[test]
    fn overflow_write_is_dropped() {
        let mut m = MockModelBackend::sparse(1, 4, 64, 32, 6, 2);
        m.prefill(&[1, 3, 4, 5], &[4]).unwrap();
        for l in 4..8 {
            m.decode(&[l], &[l], &[9]).unwrap();
        }
        // capacity 8 reached: the write is dropped (scatter OOB), counted
        assert_eq!(m.oob_writes, 0);
        m.decode(&[8], &[8], &[9]).unwrap();
        assert_eq!(m.oob_writes, 1);
        m.compress(&[1.0]).unwrap();
        // after compaction to budget 6 the write goes through again
        m.decode(&[6], &[9], &[9]).unwrap();
        assert_eq!(m.oob_writes, 1);
    }

    #[test]
    fn fault_plan_scripted_calls_fire_exactly_and_replay_on_clones() {
        let plan = FaultPlan::new()
            .scripted(FaultOp::Decode, 1, FaultKind::Err)
            .scripted(FaultOp::Decode, 2, FaultKind::Err);
        let mut m = MockModelBackend::dense(1, 4, 32, 32).with_faults(plan);
        let twin = m.clone();
        m.prefill(&[1, 3, 4, 5], &[4]).unwrap();
        assert!(m.decode(&[4], &[4], &[9]).is_ok(), "call 0 is clean");
        let e = m.decode(&[5], &[5], &[9]).unwrap_err();
        assert!(e.to_string().contains("injected fault: decode call 1"), "{e}");
        let e = m.decode(&[5], &[5], &[9]).unwrap_err();
        assert!(e.to_string().contains("injected fault: decode call 2"), "{e}");
        // the failed calls had no side effects: the retry (call 3) extends
        // the cache exactly as call 1 would have
        assert!(m.decode(&[5], &[5], &[9]).is_ok());
        assert_eq!(m.faults.as_ref().unwrap().injected_errs, 2);
        assert_eq!(m.faults.as_ref().unwrap().calls(FaultOp::Decode), 4);
        // a clone replays the identical schedule from its own counters
        let mut t = twin;
        t.prefill(&[1, 3, 4, 5], &[4]).unwrap();
        assert!(t.decode(&[4], &[4], &[9]).is_ok());
        assert!(t.decode(&[5], &[5], &[9]).is_err());
    }

    #[test]
    fn fault_plan_prompt_keyed_faults_follow_the_task() {
        let plan = FaultPlan::new().scripted_prompt(vec![1, 7, 8, 9], FaultKind::Err);
        let mut m = MockModelBackend::dense(3, 6, 32, 32).with_faults(plan);
        // every placement of the doomed prompt fails; other prompts pass
        assert!(m.prefill_slot(0, &[1, 7, 8, 9]).is_err());
        assert!(m.prefill_slot(2, &[1, 7, 8, 9]).is_err());
        assert!(m.prefill_slot(0, &[1, 7, 8]).is_ok());
        assert!(m.prepare_prefill(&[1, 7, 8, 9]).is_err());
        assert!(m.prepare_prefill(&[2, 2]).is_ok());
    }

    #[test]
    fn fault_plan_probabilistic_stream_is_seed_deterministic() {
        let mk = || {
            MockModelBackend::dense(1, 4, 32, 32)
                .with_faults(FaultPlan::new().with_error_rate(0.35, 0xFA_0175))
        };
        let (mut a, mut b) = (mk(), mk());
        let run = |m: &mut MockModelBackend| -> Vec<bool> {
            m.prefill(&[1, 3, 4, 5], &[4]).unwrap_or_default();
            (0..32).map(|_| m.decode(&[4], &[4], &[9]).is_ok()).collect()
        };
        let (ra, rb) = (run(&mut a), run(&mut b));
        assert_eq!(ra, rb, "same seed must replay the same fault stream");
        assert!(ra.iter().any(|ok| !ok), "rate 0.35 over 32 calls should fire");
        assert!(ra.iter().any(|ok| *ok), "rate 0.35 should not fire always");
        assert_eq!(
            a.faults.as_ref().unwrap().injected_errs,
            b.faults.as_ref().unwrap().injected_errs
        );
    }

    #[test]
    fn fault_plan_panic_carries_distinctive_payload() {
        let plan = FaultPlan::new().scripted(FaultOp::PrefillSlot, 0, FaultKind::Panic);
        let mut m = MockModelBackend::dense(1, 4, 32, 32).with_faults(plan);
        let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = m.prefill_slot(0, &[1, 2]);
        }))
        .unwrap_err();
        let msg = p
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("injected fault: prefill_slot call 0 panicked"), "{msg}");
    }

    #[test]
    fn compression_changes_distribution() {
        let mut m = MockModelBackend::sparse(1, 4, 64, 32, 6, 2);
        m.prefill(&[1, 3, 4, 5], &[4]).unwrap();
        for l in 4..8 {
            m.decode(&[l], &[l], &[(3 + l) as i32]).unwrap();
        }
        let before = m.decode(&[7], &[7], &[9]).unwrap();
        m.compress(&[1.0]).unwrap();
        let after = m.decode(&[5], &[8], &[9]).unwrap();
        assert_ne!(before, after);
    }
}
