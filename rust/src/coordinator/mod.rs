//! L3 coordinator: the paper's system contribution.
//!
//! * `rollout`    — batched dense/sparse generation over the AOT artifacts
//! * `scheduler`  — memory-wall admission (the batch-size story of §1)
//! * `kv_manager` — the simulated KV memory wall itself
//! * `group`      — GRPO group advantages (Eq. 10)
//! * `rejection`  — Sparsity-Aware Rejection Sampling (Eq. 5-6)
//! * `reweight`   — Importance-based Reweighting inputs (Eq. 7)
//! * `trainer`    — the full RL loop tying it together
//! * `eval`       — the 7-benchmark evaluation harness
//! * `metrics`    — training-dynamics time series (Figs. 1-6)

pub mod eval;
pub mod group;
pub mod kv_manager;
pub mod metrics;
pub mod rejection;
pub mod reweight;
pub mod rollout;
pub mod scheduler;
pub mod trainer;

pub use eval::{evaluate, evaluate_suite, EvalResult};
pub use kv_manager::KvMemoryManager;
pub use metrics::Metrics;
pub use rollout::{GenSeq, RolloutEngine};
pub use scheduler::Scheduler;
pub use trainer::{StepReport, Trainer};
