//! L3 coordinator: the paper's system contribution.
//!
//! * `engine`     — dense/sparse generation: ONE shared decode-step core
//!   (`engine::core`) under three scheduling shells — static chunked,
//!   continuous batching with slot recycling, and pipelined multi-worker
//!   batching with a dedicated prefill lane + cross-worker work stealing
//!   (all token-identical per task)
//! * `backend`    — the model surface the engines drive (artifacts or mock)
//! * `mock`       — deterministic pure-Rust backend for the equivalence
//!   test harness, the engine benches, and the chaos suite (seeded
//!   backend fault injection)
//! * `fleet`      — the replica tier: N full engine instances (scheduler
//!   + private KV wall + lane pool each) under a global load-modeled
//!   router with cross-replica work stealing
//! * `scheduler`  — memory-wall admission, chunk- and sequence-level
//!   (the batch-size story of §1)
//! * `kv_manager` — the simulated KV memory wall itself
//! * `group`      — GRPO group advantages (Eq. 10)
//! * `rejection`  — Sparsity-Aware Rejection Sampling (Eq. 5-6)
//! * `reweight`   — Importance-based Reweighting inputs (Eq. 7)
//! * `trainer`    — the full RL loop tying it together
//! * `serve`      — streaming serving front-end: deadline-aware (SLO)
//!   admission over the session rollout API, with per-request token
//!   streams and latency histograms on the virtual clock
//! * `eval`       — the 7-benchmark evaluation harness
//! * `metrics`    — training-dynamics time series (Figs. 1-6)

pub mod backend;
pub mod engine;
pub mod eval;
pub mod fleet;
pub mod group;
pub mod kv_manager;
pub mod metrics;
pub mod mock;
pub mod rejection;
pub mod reweight;
pub mod scheduler;
pub mod serve;
pub mod trainer;

pub use backend::{CostModel, EngineBackend, PreparedSlotPrefill, RolloutBackend};
pub use engine::{
    task_rng, GenSeq, LatencyHistogram, RolloutCtx, RolloutEngine, RolloutPolicy, RolloutStats,
    StreamHub, TokenEvent,
};
pub use eval::{
    evaluate, evaluate_suite, evaluate_with_backend, evaluate_with_fleet, EvalOptions, EvalResult,
};
pub use fleet::{rollout_fleet, rollout_fleet_streaming, route_tasks, FleetReport, Replica};
pub use serve::{synthetic_trace, ServeOutcome, ServeReport, ServeRequest, ServeServer, ShedReason};
pub use kv_manager::KvMemoryManager;
pub use metrics::Metrics;
pub use mock::{FaultKind, FaultOp, FaultPlan, MockModelBackend};
pub use scheduler::{AdmissionQueue, Scheduler};
pub use trainer::{StepReport, Trainer};
