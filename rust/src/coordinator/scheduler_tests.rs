// Unit tests for `coordinator::scheduler`, split out of `scheduler.rs` to
// keep the production file readable (the PR-4 convention: files stay under
// ~600 lines). Compiled as a child module of `scheduler` via `#[path]`, so
// `use super::*` resolves exactly as the old inline `mod tests` did.

use super::*;
use crate::util::propcheck;

fn fake_manifest(slots: usize, max_seq: usize, sparse_cap: usize) -> (usize, usize, usize) {
    // Scheduler only reads three numbers; tests construct it directly.
    (slots, max_seq, sparse_cap)
}

fn mk(slots: usize, reserve: usize) -> Scheduler {
    Scheduler::worst_case(slots, reserve)
}

#[test]
fn dense_is_memory_limited_sparse_is_slot_limited() {
    let (slots, max_seq, sparse_cap) = fake_manifest(16, 208, 48);
    let mut kv = KvMemoryManager::new(2048);
    let mut dense = mk(slots, max_seq);
    let mut pending: Vec<usize> = (0..16).collect();
    let c = dense.next_chunk(&mut pending, &mut kv, 0, &[]).unwrap();
    assert_eq!(c.items.len(), 9); // 2048 / 208
    dense.finish_chunk(&c, &mut kv, 0);
    assert_eq!(kv.reserved(), 0);

    let mut sparse = mk(slots, sparse_cap);
    let mut pending: Vec<usize> = (0..64).collect();
    let c = sparse.next_chunk(&mut pending, &mut kv, 100, &[]).unwrap();
    assert_eq!(c.items.len(), 16); // slot-limited, not memory-limited
    sparse.finish_chunk(&c, &mut kv, 100);
}

#[test]
fn paged_chunks_admit_by_predicted_residency() {
    // worst case 160/seq on a 480 wall admits 3; predicted residencies
    // of 80 admit 6 (slot-capped at 8)
    let mut kv = KvMemoryManager::with_pages(480, 16);
    let mut s = mk(8, 160).with_admission(AdmissionPolicy::Paged);
    let residency = vec![80usize; 12];
    let mut pending: Vec<usize> = (0..12).collect();
    let c = s.next_chunk(&mut pending, &mut kv, 0, &residency).unwrap();
    assert_eq!(c.items.len(), 6);
    assert_eq!(kv.reserved(), 6 * 80);
    kv.check_invariants().unwrap();
    s.finish_chunk(&c, &mut kv, 0);
    assert_eq!(kv.reserved(), 0);

    // mixed residencies: greedy prefix fill stops at the wall
    let residency = vec![200usize, 200, 200, 200];
    let mut pending: Vec<usize> = (0..4).collect();
    let c = s.next_chunk(&mut pending, &mut kv, 0, &residency).unwrap();
    // 200 tokens = 13 pages; 30 pages in pool -> 2 fit
    assert_eq!(c.items.len(), 2);
    s.finish_chunk(&c, &mut kv, 0);
}

#[test]
fn predicted_chunks_match_actual() {
    propcheck::quick("sched-prediction", |rng, size| {
        let slots = 1 + rng.below(32);
        let reserve = 1 + rng.below(300);
        let cap = reserve + rng.below(4096);
        let n = 1 + size;
        let mut sched = mk(slots, reserve);
        let mut kv = KvMemoryManager::new(cap);
        let mut pending: Vec<usize> = (0..n).collect();
        let mut chunks = 0usize;
        let mut scheduled = 0usize;
        while !pending.is_empty() {
            match sched.next_chunk(&mut pending, &mut kv, 1000, &[]) {
                Some(c) => {
                    chunks += 1;
                    scheduled += c.items.len();
                    // synchronous drain (static batching)
                    sched.finish_chunk(&c, &mut kv, 1000);
                }
                None => return Err("deadlock: nothing admissible".into()),
            }
            if chunks > n {
                return Err("more chunks than sequences".into());
            }
        }
        if scheduled != n {
            return Err(format!("scheduled {scheduled} of {n}"));
        }
        if chunks != sched.predicted_chunks(n, cap) {
            return Err(format!(
                "chunks {} != predicted {}",
                chunks,
                sched.predicted_chunks(n, cap)
            ));
        }
        if kv.reserved() != 0 {
            return Err("kv not fully released".into());
        }
        Ok(())
    });
}

#[test]
fn stats_track_utilization() {
    let mut kv = KvMemoryManager::new(208 * 4);
    let mut s = mk(8, 208);
    let mut pending: Vec<usize> = (0..8).collect();
    let c = s.next_chunk(&mut pending, &mut kv, 0, &[]).unwrap();
    assert_eq!(c.items.len(), 4);
    assert!((s.stats.mean_slot_utilization() - 0.5).abs() < 1e-9);
    assert!((s.stats.mean_kv_utilization() - 1.0).abs() < 1e-9);
}

#[test]
fn seq_admission_respects_wall_and_counts_stalls() {
    let mut kv = KvMemoryManager::new(100);
    let mut s = mk(8, 40);
    assert!(s.try_admit(&mut kv, 1, 10));
    assert!(s.try_admit(&mut kv, 2, 10));
    // 80 of 100 reserved: a third does not fit
    assert!(!s.try_admit(&mut kv, 3, 10));
    assert_eq!(s.stats.admit_stalls, 1);
    assert_eq!(s.stats.live_seqs(), 2);
    assert_eq!(s.release_seq(&mut kv, 1).unwrap(), 40);
    assert!(s.try_admit(&mut kv, 3, 10));
    assert_eq!(s.stats.seq_admissions, 3);
}

#[test]
fn paged_admission_charges_prompt_and_grows() {
    let mut kv = KvMemoryManager::with_pages(100, 10);
    let mut s = mk(8, 40).with_admission(AdmissionPolicy::Paged);
    // worst-case would admit 2 (40 each); paged admits 11-token
    // prompts (2 pages each) — 4 of them, keeping one page of growth
    // headroom once sequences are live
    for id in 1..=4 {
        assert!(s.try_admit(&mut kv, id, 10), "seq {id} refused");
    }
    assert_eq!(kv.used_pages(), 8);
    // 2 pages free but 2 needed + headroom: refused
    assert!(!s.try_admit(&mut kv, 5, 10));
    assert_eq!(s.stats.admit_stalls, 1);
    // growth can consume the headroom page by page
    assert!(s.grow(&mut kv, 1, 21).unwrap());
    assert!(s.grow(&mut kv, 2, 21).unwrap());
    assert_eq!(kv.free_pages(), 0);
    // pool exhausted: further growth stalls
    assert!(!s.grow(&mut kv, 3, 21).unwrap());
    assert_eq!(s.stats.grow_stalls, 1);
    // preempting a sequence frees pages for the grower
    assert_eq!(s.preempt(&mut kv, 4).unwrap(), 11);
    assert_eq!(s.stats.preemptions, 1);
    assert!(s.grow(&mut kv, 3, 21).unwrap());
    // compression shrink releases pages again
    assert!(s.compressed(&mut kv, 1, 5).unwrap());
    assert_eq!(kv.free_pages(), 3);
    kv.check_invariants().unwrap();
}

#[test]
fn admit_headroom_gates_paged_admission() {
    // pool of 10 pages; 10-token prompts charge 11 tokens = 2 pages
    let mk_kv = || KvMemoryManager::with_pages(100, 10);
    // headroom 0: admissions pack flush against the wall (5 fit)
    let mut kv = mk_kv();
    let mut s0 = mk(8, 40).with_admission(AdmissionPolicy::Paged).with_headroom(0);
    for id in 1..=5 {
        assert!(s0.try_admit(&mut kv, id, 10), "seq {id} refused at headroom 0");
    }
    assert_eq!(kv.free_pages(), 0);
    // headroom 4: every admission must leave 4 free pages -> 3 fit
    let mut kv = mk_kv();
    let mut s4 = mk(8, 40).with_admission(AdmissionPolicy::Paged).with_headroom(4);
    for id in 1..=3 {
        assert!(s4.try_admit(&mut kv, id, 10), "seq {id} refused at headroom 4");
    }
    assert!(!s4.try_admit(&mut kv, 4, 10));
    assert_eq!(kv.free_pages(), 4);
    // empty-pool bypass: even huge headroom admits a first sequence
    // (progress guarantee), then gates the second
    let mut kv = mk_kv();
    let mut sb = mk(8, 40).with_admission(AdmissionPolicy::Paged).with_headroom(100);
    assert!(sb.try_admit(&mut kv, 1, 10));
    assert!(!sb.try_admit(&mut kv, 2, 10));
    // the default reproduces the original one-page rule
    assert_eq!(mk(8, 40).admit_headroom_pages, 1);
}

#[test]
fn worst_case_grow_and_compressed_are_no_ops() {
    let mut kv = KvMemoryManager::new(100);
    let mut s = mk(4, 40);
    assert!(s.try_admit(&mut kv, 1, 10));
    assert_eq!(kv.reserved(), 40);
    assert!(s.grow(&mut kv, 1, 39).unwrap());
    assert!(s.compressed(&mut kv, 1, 5).unwrap());
    assert_eq!(kv.reserved(), 40, "worst-case reservation must not move");
    assert_eq!(s.stats.grow_stalls, 0);
}

#[test]
fn double_release_is_an_error() {
    let mut kv = KvMemoryManager::new(100);
    let mut s = mk(4, 10);
    assert!(s.try_admit(&mut kv, 7, 10));
    assert!(s.release_seq(&mut kv, 7).is_ok());
    assert!(s.release_seq(&mut kv, 7).is_err(), "double release must fail");
    assert!(s.release_seq(&mut kv, 99).is_err(), "unknown id must fail");
    assert_eq!(s.stats.seq_releases, 1);
}

#[test]
fn quarantine_seq_releases_and_counts() {
    let mut kv = KvMemoryManager::new(100);
    let mut s = mk(4, 40);
    assert!(s.try_admit(&mut kv, 1, 10));
    assert!(s.try_admit(&mut kv, 2, 10));
    // quarantine returns the reservation exactly like release_seq and
    // additionally counts toward the conservation ledger's quarantined arm
    assert_eq!(s.quarantine_seq(&mut kv, 1).unwrap(), 40);
    assert_eq!(s.stats.quarantined, 1);
    assert_eq!(kv.reserved(), 40);
    assert_eq!(s.stats.seq_releases, 1, "a quarantine IS a release");
    assert_eq!(s.stats.live_seqs(), 1);
    // quarantining an already-released id fails like a double release
    assert!(s.quarantine_seq(&mut kv, 1).is_err());
    assert_eq!(s.stats.quarantined, 1, "a failed quarantine must not count");
    s.release_seq(&mut kv, 2).unwrap();
    assert_eq!(kv.reserved(), 0);
    assert_eq!(s.stats.seq_admissions, s.stats.seq_releases);
    assert_eq!(s.stats.quarantined, 1, "plain releases never count");
    kv.check_invariants().unwrap();
}

#[test]
fn prop_seq_admission_never_deadlocks_or_leaks() {
    // Random interleavings of per-sequence admit/grow/release/preempt
    // under BOTH admission policies: admission must succeed iff the
    // wall has room for the policy's charge, reservations must
    // conserve (pages and tokens), and a full drain must always be
    // reachable (no deadlock).
    propcheck::quick("seq-admit-release", |rng, size| {
        let paged = rng.chance(0.5);
        let page = if paged { 1 + rng.below(8) } else { 1 };
        let reserve = 1 + rng.below(50);
        let cap = reserve * (1 + rng.below(8)) + rng.below(reserve);
        let mut s = mk(1 + rng.below(16), reserve);
        if paged {
            s = s.with_admission(AdmissionPolicy::Paged);
        }
        let mut kv = KvMemoryManager::with_pages(cap, page);
        // (id, reserved tokens)
        let mut live: Vec<(SeqId, usize)> = vec![];
        let mut next_id = 0u64;
        for _ in 0..(20 + size) {
            let op = if live.is_empty() { 0 } else { rng.below(4) };
            match op {
                0 | 3 => {
                    next_id += 1;
                    let prompt = rng.below(reserve.max(1));
                    let want = s.admit_reserve(prompt);
                    // paged keeps one page of growth headroom while
                    // anything is live; worst-case fills the wall
                    let fits = if paged && kv.live_sequences() > 0 {
                        kv.pages_for(want) < kv.free_pages()
                    } else {
                        kv.pages_for(want) <= kv.free_pages()
                    };
                    let admitted = s.try_admit(&mut kv, next_id, prompt);
                    if admitted != fits {
                        return Err(format!(
                            "admit said {admitted}, wall said fits={fits} \
                             (reserved {} of {cap})",
                            kv.reserved()
                        ));
                    }
                    if admitted {
                        live.push((next_id, want));
                    }
                }
                1 => {
                    // grow a random live sequence toward the bound
                    let k = rng.below(live.len());
                    let (id, cur) = live[k];
                    let target = (cur + 1 + rng.below(page * 2 + 1)).min(reserve);
                    let grown = s.grow(&mut kv, id, target).map_err(|e| e.to_string())?;
                    if grown {
                        live[k].1 = live[k].1.max(target);
                    } else if !paged {
                        return Err("worst-case grow stalled".into());
                    }
                }
                _ => {
                    let k = rng.below(live.len());
                    let (id, toks) = live.swap_remove(k);
                    let freed = if rng.chance(0.3) {
                        s.preempt(&mut kv, id).map_err(|e| e.to_string())?
                    } else {
                        s.release_seq(&mut kv, id).map_err(|e| e.to_string())?
                    };
                    if freed != toks {
                        return Err(format!("released {freed}, expected {toks}"));
                    }
                    // releasing twice must fail, not corrupt the pool
                    if s.release_seq(&mut kv, id).is_ok() {
                        return Err("double release accepted".into());
                    }
                }
            }
            let expect: usize = live.iter().map(|(_, t)| t).sum();
            if kv.reserved() != expect {
                return Err(format!("reservation leak: {} != {expect}", kv.reserved()));
            }
            if s.stats.live_seqs() != live.len() {
                return Err("live_seqs out of sync".into());
            }
            kv.check_invariants().map_err(|e| e.to_string())?;
        }
        // no deadlock: a full drain + one admission always works
        for (id, _) in live.drain(..) {
            s.release_seq(&mut kv, id).map_err(|e| e.to_string())?;
        }
        if !s.try_admit(&mut kv, u64::MAX, 0) {
            return Err("empty wall refused admission".into());
        }
        Ok(())
    });
}

#[test]
fn shared_admission_charges_prefix_once() {
    // page 4; 10-token prompts share an 8-token page-aligned prefix
    let mut kv = KvMemoryManager::with_pages(100, 4); // 25 pages
    let mut s = mk(8, 40)
        .with_admission(AdmissionPolicy::Paged)
        .with_sharing(PrefixSharing::Group);
    let prompt: Vec<i32> = (0..10).collect();
    // first sharer charges exactly the unshared admission: 11 tokens
    // = 8 prefix (2 pages) + 3 private (1 page)
    assert!(s.try_admit_prompt(&mut kv, 1, &prompt));
    assert_eq!(kv.used_pages(), 3);
    assert_eq!(s.stats.shared_admissions, 0);
    // siblings charge only their private page
    assert!(s.try_admit_prompt(&mut kv, 2, &prompt));
    assert!(s.try_admit_prompt(&mut kv, 3, &prompt));
    assert_eq!(kv.used_pages(), 5);
    assert_eq!(s.stats.shared_admissions, 2);
    assert_eq!(s.stats.seq_admissions, 3);
    // a different prompt gets its own prefix
    let other: Vec<i32> = (100..110).collect();
    assert!(s.try_admit_prompt(&mut kv, 4, &other));
    assert_eq!(kv.used_pages(), 8);
    assert_eq!(kv.live_prefixes(), 2);
    kv.check_invariants().unwrap();
    // releases drop the prefix with its last sharer
    for id in 1..=3 {
        s.release_seq(&mut kv, id).unwrap();
    }
    assert_eq!(kv.live_prefixes(), 1);
    s.release_seq(&mut kv, 4).unwrap();
    assert_eq!(kv.used_pages(), 0);
    // a drained prefix is simply re-charged fresh on its next use
    assert!(s.try_admit_prompt(&mut kv, 5, &prompt));
    assert_eq!(kv.used_pages(), 3);
    assert!(s.try_admit_prompt(&mut kv, 6, &prompt));
    assert_eq!(s.stats.shared_admissions, 3);
    kv.check_invariants().unwrap();
}

#[test]
fn sharing_off_or_worst_case_falls_back_to_plain_admission() {
    let prompt: Vec<i32> = (0..10).collect();
    // sharing off: try_admit_prompt IS try_admit
    let mut kv = KvMemoryManager::with_pages(100, 4);
    let mut s = mk(8, 40).with_admission(AdmissionPolicy::Paged);
    assert!(s.try_admit_prompt(&mut kv, 1, &prompt));
    assert!(s.try_admit_prompt(&mut kv, 2, &prompt));
    assert_eq!(kv.live_prefixes(), 0);
    assert_eq!(kv.used_pages(), 6, "both sequences pay full freight");
    // worst-case admission prices per sequence even with sharing on
    let mut kv = KvMemoryManager::new(100);
    let mut w = mk(8, 40).with_sharing(PrefixSharing::Group);
    assert!(w.try_admit_prompt(&mut kv, 1, &prompt));
    assert!(w.try_admit_prompt(&mut kv, 2, &prompt));
    assert_eq!(kv.live_prefixes(), 0);
    assert_eq!(kv.reserved(), 80);
    // sub-page prompts have no page-aligned prefix to share
    let mut kv = KvMemoryManager::with_pages(160, 16);
    let mut t = mk(8, 40)
        .with_admission(AdmissionPolicy::Paged)
        .with_sharing(PrefixSharing::Group);
    assert!(t.try_admit_prompt(&mut kv, 1, &prompt));
    assert_eq!(kv.live_prefixes(), 0);
}

#[test]
fn compressed_forks_sharers_and_shrinks_loners() {
    let mut kv = KvMemoryManager::with_pages(100, 4); // 25 pages
    let mut s = mk(8, 40)
        .with_admission(AdmissionPolicy::Paged)
        .with_sharing(PrefixSharing::Group);
    let prompt: Vec<i32> = (0..10).collect();
    assert!(s.try_admit_prompt(&mut kv, 1, &prompt));
    assert!(s.try_admit_prompt(&mut kv, 2, &prompt));
    // compression on a sharer is a CoW fork to a private residency
    assert!(s.compressed(&mut kv, 1, 6).unwrap());
    assert_eq!(s.stats.cow_forks, 1);
    assert_eq!(kv.seq_prefix(1), None);
    assert_eq!(kv.prefix_refs(0), 1, "sibling still reads the prefix");
    kv.check_invariants().unwrap();
    // …after which compression shrinks in place like any loner
    assert!(s.compressed(&mut kv, 1, 4).unwrap());
    assert_eq!(s.stats.cow_forks, 1);
    kv.check_invariants().unwrap();
    // a fork that cannot fit reports a grow stall, not an error
    let mut kv = KvMemoryManager::with_pages(20, 4); // 5 pages
    let mut s = mk(8, 40)
        .with_admission(AdmissionPolicy::Paged)
        .with_sharing(PrefixSharing::Group);
    assert!(s.try_admit_prompt(&mut kv, 1, &prompt)); // 3 pages
    assert!(s.try_admit_prompt(&mut kv, 2, &prompt)); // +1 page
    // forking seq 2 to 16 tokens needs 4 pages; 1 free + 1 own = 2
    assert!(!s.compressed(&mut kv, 2, 16).unwrap());
    assert_eq!(s.stats.grow_stalls, 1);
    assert_eq!(s.stats.cow_forks, 0);
    assert_eq!(kv.seq_prefix(2), Some(0), "denied fork left state alone");
    kv.check_invariants().unwrap();
}

#[test]
fn predicted_decode_steps_closed_forms() {
    // width 2, queue costs (len-1) = [4, 1, 1, 1]:
    // slot recycling packs the three short ones behind each other
    let s = mk(2, 10);
    assert_eq!(s.predicted_decode_steps(&[5, 2, 2, 2], 1000), 4);
    // static chunks [5,2],[2,2]: (5-1) + (2-1)
    assert_eq!(s.predicted_decode_steps_static(&[5, 2, 2, 2], 1000), 5);
    // KV-limited to width 1: both degenerate to the serial sum
    assert_eq!(s.predicted_decode_steps(&[5, 2, 2, 2], 10), 7);
    assert_eq!(s.predicted_decode_steps_static(&[5, 2, 2, 2], 10), 7);
    // uniform lengths: continuous gains nothing
    assert_eq!(
        s.predicted_decode_steps(&[4, 4, 4, 4], 1000),
        s.predicted_decode_steps_static(&[4, 4, 4, 4], 1000)
    );
    // single-token sequences cost zero decode steps
    assert_eq!(s.predicted_decode_steps(&[1, 1, 1], 1000), 0);
    assert_eq!(s.predicted_decode_steps(&[], 1000), 0);
    // the width model: a tighter per-seq reservation widens the batch
    let wide = mk(8, 100);
    assert!(
        wide.predicted_decode_steps_with(&[9; 16], 300, 30)
            < wide.predicted_decode_steps_with(&[9; 16], 300, 100)
    );
}

#[test]
fn pick_next_orders_by_admission_cost() {
    let fifo = mk(4, 100);
    let sjf = mk(4, 100).with_order(AdmissionOrder::ShortestFirst);
    // cost indexed by TASK position; queue holds task positions
    let cost = vec![80usize, 20, 50, 20];
    let queue: VecDeque<usize> = vec![0, 1, 2, 3].into();
    assert_eq!(fifo.pick_next(&queue, &cost), Some(0));
    // shortest-first: task 1 (cost 20) wins; the tie with task 3
    // breaks toward the earlier queue position (stable)
    assert_eq!(sjf.pick_next(&queue, &cost), Some(1));
    let queue: VecDeque<usize> = vec![3, 0, 1].into();
    assert_eq!(sjf.pick_next(&queue, &cost), Some(0), "task 3 at qi 0");
    let empty: VecDeque<usize> = VecDeque::new();
    assert_eq!(fifo.pick_next(&empty, &cost), None);
    assert_eq!(sjf.pick_next(&empty, &cost), None);
    // reservation oracle caps at the per-seq bound; the ordering key
    // does not, so cap-tied tasks still order by prompt size
    assert_eq!(sjf.predicted_residency(10, 20), 31);
    assert_eq!(sjf.predicted_residency(90, 20), 100);
    assert_eq!(sjf.admission_cost(10, 20), 31);
    assert_eq!(sjf.admission_cost(90, 20), 111);
    assert!(sjf.admission_cost(80, 20) < sjf.admission_cost(90, 20));
}

/// The reference pop: `pick_next` over a plain deque (the pre-index
/// semantics the sorted AdmissionQueue must reproduce exactly).
fn reference_pop(sched: &Scheduler, q: &mut VecDeque<usize>, cost: &[usize]) -> Option<usize> {
    let qi = sched.pick_next(q, cost)?;
    let pos = q[qi];
    q.remove(qi);
    Some(pos)
}

#[test]
fn admission_queue_pins_stable_first_min_tie_break() {
    // costs by task position: three cost-3 ties (tasks 1, 2, 3)
    let cost = vec![5usize, 3, 3, 3, 5, 1];
    let mut q = AdmissionQueue::new(AdmissionOrder::ShortestFirst, cost.clone());
    assert_eq!(q.len(), 6);
    // global min first, then the tie group in queue order
    assert_eq!(q.peek(), Some(5));
    assert_eq!(q.pop(), Some(5));
    assert_eq!(q.pop(), Some(1), "first of the cost-3 tie group");
    // a preempted task requeued at the head wins its tie group again
    q.push_front(1);
    assert_eq!(q.pop(), Some(1), "push_front must win equal-cost ties");
    assert_eq!(q.pop(), Some(2));
    assert_eq!(q.pop(), Some(3));
    assert_eq!(q.pop(), Some(0), "cost-5 ties keep original queue order");
    assert_eq!(q.pop(), Some(4));
    assert_eq!(q.pop(), None);
    assert!(q.is_empty());

    // fifo mode ignores costs entirely
    let mut f = AdmissionQueue::new(AdmissionOrder::Fifo, cost);
    f.push_front(4);
    assert_eq!(f.pop(), Some(4));
    assert_eq!(f.pop(), Some(0));
    assert_eq!(f.pop(), Some(1));
}

#[test]
fn prop_admission_queue_matches_pick_next_reference() {
    // Random pop / push_front traffic (the only operations the
    // engines perform) over heavily tied cost vectors: the sorted
    // index must emit exactly the reference scan's pick sequence, in
    // both admission orders.
    propcheck::quick("admission-queue-oracle", |rng, size| {
        let n = 1 + rng.below(4 + size);
        // few distinct costs -> many ties -> the tie-break is what's
        // actually under test
        let cost: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
        for order in [AdmissionOrder::Fifo, AdmissionOrder::ShortestFirst] {
            let sched = mk(4, 100).with_order(order);
            let mut q = AdmissionQueue::new(order, cost.clone());
            let mut reference: VecDeque<usize> = (0..n).collect();
            let mut popped: Vec<usize> = Vec::new();
            for _ in 0..(2 * n + 10) {
                if !popped.is_empty() && rng.chance(0.3) {
                    // requeue a random previously-popped task (the
                    // preemption path)
                    let pos = popped.swap_remove(rng.below(popped.len()));
                    q.push_front(pos);
                    reference.push_front(pos);
                } else {
                    let got = q.pop();
                    let want = reference_pop(&sched, &mut reference, &cost);
                    if got != want {
                        return Err(format!(
                            "{}: index popped {got:?}, reference {want:?} (cost {cost:?})",
                            order.label()
                        ));
                    }
                    if let Some(pos) = got {
                        popped.push(pos);
                    }
                }
                if q.len() != reference.len() {
                    return Err(format!(
                        "len diverged: index {} vs reference {}",
                        q.len(),
                        reference.len()
                    ));
                }
            }
            // full drain must also agree
            while let Some(want) = reference_pop(&sched, &mut reference, &cost) {
                if q.pop() != Some(want) {
                    return Err("drain order diverged".into());
                }
            }
            if q.pop().is_some() {
                return Err("index longer than reference".into());
            }
        }
        Ok(())
    });
}

#[test]
fn width_paged_tracks_mean_residency() {
    let s = mk(8, 160);
    let kv = KvMemoryManager::with_pages(480, 16);
    // worst case: 480/160 = 3 wide; paged at mean residency 80: 6 wide
    assert_eq!(s.width_paged(&kv, 160), 3);
    assert_eq!(s.width_paged(&kv, 80), 6);
    assert_eq!(s.width_paged(&kv, 10), 8, "slot-capped");
}

#[test]
fn pick_next_deadline_orders_by_edf_then_cost_then_queue() {
    let s = mk(4, 100);
    // deadlines/cost indexed by TASK position; queue holds positions
    let cost = vec![80usize, 20, 50, 20];
    let deadline = vec![900u64, 500, 500, 100];
    let queue: VecDeque<usize> = vec![0, 1, 2, 3].into();
    // earliest deadline wins regardless of cost or queue position
    assert_eq!(s.pick_next_deadline(&queue, &cost, &deadline), Some(3));
    // deadline tie (tasks 1 and 2 at 500): cheaper cost wins
    let queue: VecDeque<usize> = vec![0, 2, 1].into();
    let deadline = vec![900u64, 500, 500, 100];
    assert_eq!(s.pick_next_deadline(&queue, &cost, &deadline), Some(2), "cost 20 beats 50");
    // deadline AND cost tie: earlier queue position wins (stable)
    let cost = vec![20usize, 20, 20];
    let deadline = vec![500u64, 500, 500];
    let queue: VecDeque<usize> = vec![2, 0, 1].into();
    assert_eq!(s.pick_next_deadline(&queue, &cost, &deadline), Some(0), "stable first-min");
    // a missing deadline entry reads as infinite — it never preempts a
    // task with a real deadline
    let queue: VecDeque<usize> = vec![4, 1].into(); // task 4 out of range
    assert_eq!(s.pick_next_deadline(&queue, &cost, &deadline), Some(1));
    // the picker ignores the scheduler's own admission order knob — the
    // serve-admission knob decides who calls it, not how it sorts
    let sjf = mk(4, 100).with_order(AdmissionOrder::ShortestFirst);
    let cost = vec![80usize, 20];
    let deadline = vec![100u64, 900];
    let queue: VecDeque<usize> = vec![0, 1].into();
    assert_eq!(sjf.pick_next_deadline(&queue, &cost, &deadline), Some(0));
    let empty: VecDeque<usize> = VecDeque::new();
    assert_eq!(s.pick_next_deadline(&empty, &cost, &deadline), None);
}

#[test]
fn predicted_cost_ticks_is_residency_times_admission_cost() {
    let s = mk(8, 100);
    // below the cap: (p + r + 1)^2; at the cap: reserve * (p + r + 1) —
    // the same product the fleet router's load model charges per task
    assert_eq!(s.predicted_cost_ticks(10, 20), 31 * 31);
    assert_eq!(s.predicted_cost_ticks(90, 20), 100 * 111);
    // monotone in prompt length (shed decisions must be order-sane)
    assert!(s.predicted_cost_ticks(80, 20) < s.predicted_cost_ticks(90, 20));
}

#[test]
fn prop_pick_next_deadline_degenerates_to_shortest_first() {
    // With every deadline infinite, the EDF key collapses to
    // (cost, queue order) — exactly `pick_next` under ShortestFirst.
    // The existing picker is the oracle, over heavily tied costs.
    propcheck::quick("deadline-degenerates-to-sjf", |rng, size| {
        let n = 1 + rng.below(4 + size);
        let cost: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
        let deadline = vec![u64::MAX; n];
        let sjf = mk(4, 100).with_order(AdmissionOrder::ShortestFirst);
        // drive both pickers through a full random-order drain
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut queue: VecDeque<usize> = order.into_iter().collect();
        while !queue.is_empty() {
            let got = sjf.pick_next_deadline(&queue, &cost, &deadline);
            let want = sjf.pick_next(&queue, &cost);
            if got != want {
                return Err(format!(
                    "infinite deadlines diverged from shortest-first: \
                     {got:?} != {want:?} (cost {cost:?}, queue {queue:?})"
                ));
            }
            let _ = queue.remove(got.ok_or("picker returned None on non-empty queue")?);
        }
        Ok(())
    });
}

#[test]
fn continuous_never_worse_than_static_prediction() {
    propcheck::quick("continuous-leq-static", |rng, size| {
        let s = mk(1 + rng.below(8), 1 + rng.below(64));
        let cap = 1 + rng.below(512);
        let lens: Vec<usize> = (0..1 + size).map(|_| 1 + rng.below(40)).collect();
        let c = s.predicted_decode_steps(&lens, cap);
        let st = s.predicted_decode_steps_static(&lens, cap);
        if c > st {
            return Err(format!("continuous {c} > static {st} for {lens:?}"));
        }
        Ok(())
    });
}
