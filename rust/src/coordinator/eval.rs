//! Benchmark evaluation harness (paper §5.1 Evaluation).
//!
//! Pass@1 benchmarks decode greedily; Avg@k benchmarks (AIME24/AMC23 ->
//! Avg@32) sample k responses at temperature 1.0 and average accuracy per
//! item. Evaluation can run in dense mode (Table 1) or under the same KV
//! compression as training (Table 2's "sparse inference" deployment
//! scenario).

use anyhow::Result;

use crate::config::{RolloutMode, SamplingConfig};
use crate::data::benchmarks::{Benchmark, Protocol};
use crate::data::task::Task;
use crate::runtime::ModelEngine;

use super::rollout::RolloutEngine;

/// Result of evaluating one benchmark.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub benchmark: String,
    pub accuracy: f64,
    pub items: usize,
    pub samples: usize,
    pub mean_response_len: f64,
    pub toks_saving: f64,
}

/// Evaluate `params` on a benchmark under the given rollout mode.
///
/// `limit` caps the number of items (0 = full benchmark) so smoke tests
/// and quick benches stay fast; EXPERIMENTS.md records which limit a run
/// used.
pub fn evaluate(
    engine: &ModelEngine,
    params: &[f32],
    mode: RolloutMode,
    bench: &Benchmark,
    limit: usize,
    seed: u64,
) -> Result<EvalResult> {
    let m = &engine.manifest;
    let mut tasks = bench.tasks(m.config.prompt_len);
    if limit > 0 && tasks.len() > limit {
        tasks.truncate(limit);
    }
    // Quick mode (limit > 0) also caps Avg@k sampling at k=4 — the full
    // paper protocol (Avg@32) runs with limit = 0. EXPERIMENTS.md records
    // which mode produced each number.
    let k = if limit > 0 {
        bench.samples_per_item().min(4)
    } else {
        bench.samples_per_item()
    };
    let sampling = match bench.protocol {
        Protocol::Pass1 => SamplingConfig {
            temperature: 0.0, // greedy
            top_p: 1.0,
            max_response: m.config.max_seq - m.config.prompt_len,
        },
        Protocol::AvgK(_) => SamplingConfig {
            temperature: 1.0,
            top_p: 1.0,
            max_response: m.config.max_seq - m.config.prompt_len,
        },
    };
    let rollout = RolloutEngine::new(engine, mode, sampling);
    // per-task RNG streams key off (rollout seed, flat sample id), so
    // every Avg@k sample draws an independent, reproducible stream
    let rollout_seed = seed ^ 0xE7A1_5EED;

    // flat sample list: item i sample j -> flat i*k + j
    let flat: Vec<(usize, &Task)> = (0..tasks.len() * k)
        .map(|s| (s, &tasks[s / k]))
        .collect();
    let r = m.shapes.decode_batch;
    let mut correct_per_item = vec![0usize; tasks.len()];
    let mut total_len = 0usize;
    let mut acct = crate::compression::KvAccounting::new();
    for chunk in flat.chunks(r) {
        let seqs = rollout.rollout_chunk(params, chunk, rollout_seed)?;
        for seq in seqs {
            let item = seq.task_idx / k;
            if tasks[item].reward(&seq.response_ids) > 0.5 {
                correct_per_item[item] += 1;
            }
            total_len += seq.response_ids.len();
            acct.merge(&seq.accounting);
        }
    }
    let accuracy = correct_per_item
        .iter()
        .map(|&c| c as f64 / k as f64)
        .sum::<f64>()
        / tasks.len() as f64;
    Ok(EvalResult {
        benchmark: bench.name.to_string(),
        accuracy,
        items: tasks.len(),
        samples: tasks.len() * k,
        mean_response_len: total_len as f64 / (tasks.len() * k) as f64,
        toks_saving: acct.toks_saving(),
    })
}

/// Evaluate a full suite; returns (per-benchmark results, macro average).
pub fn evaluate_suite(
    engine: &ModelEngine,
    params: &[f32],
    mode: RolloutMode,
    suite: &[Benchmark],
    limit: usize,
    seed: u64,
) -> Result<(Vec<EvalResult>, f64)> {
    let mut results = Vec::new();
    for b in suite {
        let r = evaluate(engine, params, mode, b, limit, seed)?;
        println!(
            "  {:<10} acc {:>6.3}  ({} items, {} samples, len {:.1})",
            r.benchmark, r.accuracy, r.items, r.samples, r.mean_response_len
        );
        results.push(r);
    }
    let avg = results.iter().map(|r| r.accuracy).sum::<f64>() / results.len().max(1) as f64;
    Ok((results, avg))
}
