//! Benchmark evaluation harness (paper §5.1 Evaluation).
//!
//! Pass@1 benchmarks decode greedily; Avg@k benchmarks (AIME24/AMC23 ->
//! Avg@32) sample k responses at temperature 1.0 and average accuracy per
//! item. Evaluation can run in dense mode (Table 1) or under the same KV
//! compression as training (Table 2's "sparse inference" deployment
//! scenario), and — like the trainer — on any rollout engine
//! (`EvalOptions::engine`): Avg@k benchmarks have exactly the
//! skewed-length profile slot recycling exploits, so `continuous` (and
//! `pipelined`, across `rollout_workers` lanes) shaves decode steps
//! without changing a single token (per-task RNG).
//!
//! The scoring core (`evaluate_with_backend`) is generic over
//! `RolloutBackend`, so the engine-dispatch and empty-benchmark guards are
//! exercised hermetically on the mock backend by `tests/paged_kv.rs`.

use anyhow::{bail, Result};

use crate::config::{
    AdmissionOrder, EngineKind, ExperimentConfig, FaultPolicy, MemoryConfig, PrefillMode,
    RolloutMode, SamplingConfig,
};
use crate::data::benchmarks::{Benchmark, Protocol};
use crate::data::task::Task;
use crate::runtime::{ModelEngine, ParamsLit};

use super::backend::{EngineBackend, RolloutBackend};
use super::engine::{GenSeq, RolloutCtx, RolloutPolicy};
use super::fleet::{rollout_fleet, Replica};
use super::kv_manager::KvMemoryManager;
use super::scheduler::Scheduler;

/// Result of evaluating one benchmark.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub benchmark: String,
    pub accuracy: f64,
    pub items: usize,
    pub samples: usize,
    pub mean_response_len: f64,
    pub toks_saving: f64,
}

impl EvalResult {
    /// The well-defined result for a benchmark with nothing to score:
    /// zero items, zero accuracy — never NaN (an unguarded mean over an
    /// empty benchmark used to poison the suite macro-average).
    pub fn empty(benchmark: &str) -> EvalResult {
        EvalResult {
            benchmark: benchmark.to_string(),
            accuracy: 0.0,
            items: 0,
            samples: 0,
            mean_response_len: 0.0,
            toks_saving: 0.0,
        }
    }
}

/// Engine/memory knobs for evaluation, mirroring what the trainer reads
/// from `ExperimentConfig`. Defaults preserve the original behavior:
/// static chunking, worst-case admission, token-granular wall (and two
/// decode lanes if `engine = pipelined` is selected).
#[derive(Debug, Clone, Copy)]
pub struct EvalOptions {
    pub engine: EngineKind,
    pub memory: MemoryConfig,
    /// Decode lanes for `engine = pipelined`; ignored otherwise.
    pub rollout_workers: usize,
    /// Cross-worker work stealing for `engine = pipelined` (default on).
    pub steal: bool,
    /// Admission order for the pending queue (fifo preserves the
    /// original behavior).
    pub admission_order: AdmissionOrder,
    /// Slot-prefill execution for `engine = pipelined` (sync preserves
    /// the original blocking behavior; async runs the dedicated
    /// prefill-executor thread).
    pub prefill: PrefillMode,
    /// Data-parallel rollout replicas (the `replicas` knob): each
    /// replica gets its own scheduler + KV wall + lane pool and a global
    /// router splits the sample list by modeled load. Default 1 = the
    /// single-engine path. Tokens are replica-count-invariant.
    pub replicas: usize,
    /// Cross-replica work stealing for `replicas > 1` (default on).
    pub replica_steal: bool,
    /// Bounded-retry budget for failing backend calls (`fault-retries`;
    /// default 0 = the bare-call seed behavior).
    pub fault_retries: usize,
    /// Chunked-prefill token budget (`prefill-chunk-tokens`; default 0 =
    /// monolithic slot prefills). Scheduling-only: accuracy and every
    /// sampled token are budget-invariant.
    pub prefill_chunk_tokens: usize,
    /// What happens when a call exhausts its retries: `abort` (default —
    /// the error kills the eval) or `quarantine` (the sample is recorded
    /// failed; with fleets, dead replicas fail over to survivors).
    pub fault_policy: FaultPolicy,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions {
            engine: EngineKind::default(),
            memory: MemoryConfig::default(),
            rollout_workers: 2,
            steal: true,
            admission_order: AdmissionOrder::default(),
            prefill: PrefillMode::default(),
            replicas: 1,
            replica_steal: true,
            fault_retries: 0,
            prefill_chunk_tokens: 0,
            fault_policy: FaultPolicy::default(),
        }
    }
}

impl EvalOptions {
    /// Mirror every engine / memory / fleet / fault knob the trainer
    /// reads from `ExperimentConfig`. The one construction site that
    /// tracks the full field list lives here — callers (the `eval`
    /// subcommand, harnesses) stop rippling when a knob is added.
    pub fn from_config(cfg: &ExperimentConfig) -> EvalOptions {
        EvalOptions {
            engine: cfg.engine,
            memory: cfg.memory,
            rollout_workers: cfg.rollout_workers,
            steal: cfg.steal,
            admission_order: cfg.admission_order,
            prefill: cfg.prefill,
            replicas: cfg.replicas,
            replica_steal: cfg.replica_steal,
            fault_retries: cfg.fault_retries,
            prefill_chunk_tokens: cfg.prefill_chunk_tokens,
            fault_policy: cfg.fault_policy,
        }
    }

    /// Builder over [`Default`] (or [`EvalOptions::from_config`]) for the
    /// handful of knobs a harness actually overrides — avoids 11-field
    /// struct literals at every test/bench call site.
    pub fn with_engine(mut self, engine: EngineKind) -> EvalOptions {
        self.engine = engine;
        self
    }
    pub fn with_memory(mut self, memory: MemoryConfig) -> EvalOptions {
        self.memory = memory;
        self
    }
    pub fn with_workers(mut self, workers: usize) -> EvalOptions {
        self.rollout_workers = workers;
        self
    }
    pub fn with_replicas(mut self, replicas: usize) -> EvalOptions {
        self.replicas = replicas;
        self
    }
}

/// Fold rolled-out samples into the per-item accuracy / length /
/// savings summary. `seqs` carry flat sample ids (item `i` sample `j`
/// at `i*k + j`), in any order — the fold keys off `task_idx`, so the
/// single-engine and fleet paths score identically. A quarantined sample
/// (`fault-policy = quarantine`) simply scores incorrect — eval has no
/// group structure to drop, so partial delivery degrades accuracy
/// instead of erroring.
fn score_rollouts(benchmark: &str, tasks: &[Task], k: usize, seqs: Vec<GenSeq>) -> EvalResult {
    let mut correct_per_item = vec![0usize; tasks.len()];
    let mut total_len = 0usize;
    let mut acct = crate::compression::KvAccounting::new();
    for seq in seqs {
        let item = seq.task_idx / k;
        if tasks[item].reward(&seq.response_ids) > 0.5 {
            correct_per_item[item] += 1;
        }
        total_len += seq.response_ids.len();
        acct.merge(&seq.accounting);
    }
    let accuracy = correct_per_item
        .iter()
        .map(|&c| c as f64 / k as f64)
        .sum::<f64>()
        / tasks.len() as f64;
    EvalResult {
        benchmark: benchmark.to_string(),
        accuracy,
        items: tasks.len(),
        samples: tasks.len() * k,
        mean_response_len: total_len as f64 / (tasks.len() * k) as f64,
        toks_saving: acct.toks_saving(),
    }
}

/// Backend-generic evaluation core: roll out `k` samples per task on the
/// requested engine and fold per-item accuracy. Returns
/// [`EvalResult::empty`] — not NaN — when there is nothing to score.
///
/// `backends` carries one backend per decode lane: the single-lane
/// engines use `backends[0]`, the pipelined engine uses them all (which
/// is why the bound is `Send` — lanes are worker threads). When the
/// policy selects `prefill = async`, the LAST backend is the dedicated
/// prefill-executor lane (so pipelined callers pass `workers + 1`
/// backends).
#[allow(clippy::too_many_arguments)]
pub fn evaluate_with_backend<B: RolloutBackend + Send>(
    policy: &RolloutPolicy,
    backends: &mut [B],
    engine_kind: EngineKind,
    sched: &mut Scheduler,
    kv: &mut KvMemoryManager,
    benchmark: &str,
    tasks: &[Task],
    k: usize,
    rollout_seed: u64,
) -> Result<EvalResult> {
    if backends.is_empty() {
        bail!("evaluate_with_backend needs at least one backend lane");
    }
    if tasks.is_empty() || k == 0 {
        return Ok(EvalResult::empty(benchmark));
    }
    // flat sample list: item i sample j -> flat i*k + j; per-task RNG
    // streams key off the flat id, so every Avg@k sample draws an
    // independent, reproducible stream on any engine
    let flat: Vec<(usize, &Task)> = (0..tasks.len() * k)
        .map(|s| (s, &tasks[s / k]))
        .collect();
    let (seqs, _stats) = match engine_kind {
        EngineKind::Static => {
            let ctx = RolloutCtx::new(sched, kv);
            policy.rollout_static_queue(&mut backends[0], &flat, rollout_seed, ctx)?
        }
        EngineKind::Continuous => {
            let ctx = RolloutCtx::new(sched, kv);
            policy.rollout_continuous(&mut backends[0], &flat, rollout_seed, ctx)?
        }
        EngineKind::Pipelined => {
            let ctx = RolloutCtx::new(sched, kv);
            if policy.prefill.is_async() {
                if backends.len() < 2 {
                    bail!("pipelined async eval needs worker lanes + one executor backend");
                }
                let (workers, exec) = backends.split_at_mut(backends.len() - 1);
                policy.rollout_pipelined(workers, Some(&mut exec[0]), &flat, rollout_seed, ctx)?
            } else {
                policy.rollout_pipelined(backends, None, &flat, rollout_seed, ctx)?
            }
        }
    };
    Ok(score_rollouts(benchmark, tasks, k, seqs))
}

/// Fleet-path evaluation core: roll the flat sample list out across a
/// replica fleet (`rollout_fleet` routes by modeled load and, when
/// `replica_steal`, rebalances stragglers) and fold accuracy with the
/// same scorer as `evaluate_with_backend` — per-task RNG makes the two
/// paths sample-for-sample identical.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_with_fleet<B: RolloutBackend + Send>(
    policy: &RolloutPolicy,
    replicas: &mut [Replica<B>],
    engine_kind: EngineKind,
    replica_steal: bool,
    benchmark: &str,
    tasks: &[Task],
    k: usize,
    rollout_seed: u64,
) -> Result<EvalResult> {
    if tasks.is_empty() || k == 0 {
        return Ok(EvalResult::empty(benchmark));
    }
    let flat: Vec<(usize, &Task)> = (0..tasks.len() * k)
        .map(|s| (s, &tasks[s / k]))
        .collect();
    let (seqs, _stats, _report) =
        rollout_fleet(policy, engine_kind, replicas, &flat, rollout_seed, replica_steal)?;
    Ok(score_rollouts(benchmark, tasks, k, seqs))
}

/// Evaluate `params` on a benchmark under the given rollout mode.
///
/// `limit` caps the number of items (0 = full benchmark) so smoke tests
/// and quick benches stay fast; EXPERIMENTS.md records which limit a run
/// used. `opts` selects the rollout engine and memory-wall knobs (the
/// trainer's `engine` / `admission` / `kv-page-tokens` config keys apply
/// to evaluation too).
pub fn evaluate(
    engine: &ModelEngine,
    params: &[f32],
    mode: RolloutMode,
    bench: &Benchmark,
    limit: usize,
    seed: u64,
    opts: &EvalOptions,
) -> Result<EvalResult> {
    let m = &engine.manifest;
    let mut tasks = bench.tasks(m.config.prompt_len);
    if limit > 0 && tasks.len() > limit {
        tasks.truncate(limit);
    }
    // Quick mode (limit > 0) also caps Avg@k sampling at k=4 — the full
    // paper protocol (Avg@32) runs with limit = 0. EXPERIMENTS.md records
    // which mode produced each number.
    let k = if limit > 0 {
        bench.samples_per_item().min(4)
    } else {
        bench.samples_per_item()
    }
    .max(1);
    let sampling = match bench.protocol {
        Protocol::Pass1 => SamplingConfig {
            temperature: 0.0, // greedy
            top_p: 1.0,
            max_response: m.config.max_seq - m.config.prompt_len,
        },
        Protocol::AvgK(_) => SamplingConfig {
            temperature: 1.0,
            top_p: 1.0,
            max_response: m.config.max_seq - m.config.prompt_len,
        },
    };
    let policy = RolloutPolicy::new(mode, sampling)
        .with_steal(opts.steal)
        .with_prefill(opts.prefill)
        .with_sharing(opts.memory.prefix_sharing)
        .with_fault_retries(opts.fault_retries)
        .with_prefill_chunk_tokens(opts.prefill_chunk_tokens)
        .with_fault_policy(opts.fault_policy);
    let params_lit = ParamsLit::new(params);
    // one backend per decode lane (single-lane engines use the first);
    // pipelined async adds one more for the prefill-executor thread
    let decode_lanes = if opts.engine == EngineKind::Pipelined {
        opts.rollout_workers.max(1)
    } else {
        1
    };
    let lanes = if opts.engine == EngineKind::Pipelined && opts.prefill.is_async() {
        decode_lanes + 1
    } else {
        decode_lanes
    };
    let mk_sched = || {
        Scheduler::new(m, mode.is_sparse())
            .with_admission(opts.memory.admission)
            .with_headroom(opts.memory.kv_admit_headroom_pages)
            .with_order(opts.admission_order)
            .with_sharing(opts.memory.prefix_sharing)
    };
    // The eval wall exists to drive the engines' admission machinery, not
    // to throttle accuracy measurement (tokens are width-independent). It
    // is clamped up so a full decode batch always fits — with default
    // options the static engine therefore chunks by decode_batch exactly
    // like the pre-wall eval path did, and a small configured wall can
    // never turn a previously-working eval into a "stalled" error.
    let page = opts.memory.kv_page_tokens;
    let per_seq_pages_tokens = mk_sched().reserve_per_seq.div_ceil(page) * page;
    // (for pipelined, clamp per DECODE lane so every worker can fill its
    // batch — the executor lane holds no admissions; replica walls are
    // private, so the clamp applies per replica, not to their sum)
    let wall = opts
        .memory
        .global_kv_tokens
        .max(per_seq_pages_tokens * m.shapes.decode_batch * decode_lanes);
    if opts.replicas > 1 {
        let mut replicas: Vec<Replica<EngineBackend>> = (0..opts.replicas)
            .map(|_| {
                let backends = (0..lanes)
                    .map(|_| EngineBackend::new(engine, &params_lit, mode))
                    .collect();
                Replica::new(mk_sched(), KvMemoryManager::with_pages(wall, page), backends)
            })
            .collect();
        return evaluate_with_fleet(
            &policy,
            &mut replicas,
            opts.engine,
            opts.replica_steal,
            bench.name,
            &tasks,
            k,
            seed ^ 0xE7A1_5EED,
        );
    }
    let mut backends: Vec<EngineBackend> = (0..lanes)
        .map(|_| EngineBackend::new(engine, &params_lit, mode))
        .collect();
    let mut sched = mk_sched();
    let mut kv = KvMemoryManager::with_pages(wall, page);
    evaluate_with_backend(
        &policy,
        &mut backends,
        opts.engine,
        &mut sched,
        &mut kv,
        bench.name,
        &tasks,
        k,
        seed ^ 0xE7A1_5EED,
    )
}

/// Evaluate a full suite; returns (per-benchmark results, macro average).
/// Zero-item benchmarks are reported but excluded from the macro average
/// (they carry no signal; averaging them in used to produce NaN).
pub fn evaluate_suite(
    engine: &ModelEngine,
    params: &[f32],
    mode: RolloutMode,
    suite: &[Benchmark],
    limit: usize,
    seed: u64,
    opts: &EvalOptions,
) -> Result<(Vec<EvalResult>, f64)> {
    let mut results = Vec::new();
    for b in suite {
        let r = evaluate(engine, params, mode, b, limit, seed, opts)?;
        println!(
            "  {:<10} acc {:>6.3}  ({} items, {} samples, len {:.1})",
            r.benchmark, r.accuracy, r.items, r.samples, r.mean_response_len
        );
        results.push(r);
    }
    let counted: Vec<f64> = results
        .iter()
        .filter(|r| r.items > 0)
        .map(|r| r.accuracy)
        .collect();
    let avg = if counted.is_empty() {
        0.0
    } else {
        counted.iter().sum::<f64>() / counted.len() as f64
    };
    Ok((results, avg))
}
