//! KV-storage accounting: the numbers behind the paper's "Toks. saving"
//! column and the memory-wall analysis.
//!
//! Two views are tracked per rollout:
//!  * **integral** — token-steps of KV storage (sum over decode steps of
//!    resident KV tokens), the quantity that determines sustained memory
//!    pressure and therefore admissible batch width;
//!  * **peak** — maximum simultaneous resident tokens for one sequence,
//!    the quantity that determines worst-case (OOM) reservation.
//!
//! "Toks. saving" (Table 1) = 1 - sparse_integral / dense_integral, where
//! the dense integral is reconstructed from the same generation lengths —
//! i.e. exactly "reduction in stored KV tokens compared to the generation
//! length of the dense rollout" at matched lengths.

/// Accumulates KV residency for a set of sequences.
#[derive(Debug, Clone, Default)]
pub struct KvAccounting {
    /// Σ over steps of resident tokens (actual, with compression).
    pub integral_actual: u64,
    /// Σ over steps of resident tokens had the cache been dense.
    pub integral_dense: u64,
    /// Max resident tokens for any single sequence at any step (actual).
    pub peak_actual: usize,
    /// Max resident tokens for any single sequence at any step (dense).
    pub peak_dense: usize,
    /// Number of decode steps accounted.
    pub steps: u64,
    /// Number of compressions performed.
    pub compressions: u64,
    /// Tokens evicted across all compressions.
    pub evicted: u64,
}

impl KvAccounting {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one decode step for one sequence.
    ///
    /// `resident` = occupied cache slots after the step (compressed path);
    /// `dense_equiv` = what a dense cache would hold (prompt + generated).
    pub fn step(&mut self, resident: usize, dense_equiv: usize) {
        self.integral_actual += resident as u64;
        self.integral_dense += dense_equiv as u64;
        self.peak_actual = self.peak_actual.max(resident);
        self.peak_dense = self.peak_dense.max(dense_equiv);
        self.steps += 1;
    }

    /// Record a compression event that dropped `evicted` tokens.
    pub fn compression(&mut self, evicted: usize) {
        self.compressions += 1;
        self.evicted += evicted as u64;
    }

    /// Fractional reduction in stored KV token-steps vs dense (paper's
    /// "Toks. saving"). 0 when nothing was tracked.
    pub fn toks_saving(&self) -> f64 {
        if self.integral_dense == 0 {
            return 0.0;
        }
        1.0 - self.integral_actual as f64 / self.integral_dense as f64
    }

    /// Peak-memory reduction factor (drives the admissible batch ratio).
    pub fn peak_saving(&self) -> f64 {
        if self.peak_dense == 0 {
            return 0.0;
        }
        1.0 - self.peak_actual as f64 / self.peak_dense as f64
    }

    pub fn merge(&mut self, other: &KvAccounting) {
        self.integral_actual += other.integral_actual;
        self.integral_dense += other.integral_dense;
        self.peak_actual = self.peak_actual.max(other.peak_actual);
        self.peak_dense = self.peak_dense.max(other.peak_dense);
        self.steps += other.steps;
        self.compressions += other.compressions;
        self.evicted += other.evicted;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_rollout_saves_nothing() {
        let mut a = KvAccounting::new();
        for t in 10..50 {
            a.step(t, t);
        }
        assert_eq!(a.toks_saving(), 0.0);
        assert_eq!(a.peak_actual, 49);
    }

    #[test]
    fn capped_rollout_saves() {
        let mut a = KvAccounting::new();
        let cap = 48;
        for t in 10..200usize {
            a.step(t.min(cap), t);
        }
        assert!(a.toks_saving() > 0.4, "saving {}", a.toks_saving());
        assert_eq!(a.peak_actual, cap);
        assert_eq!(a.peak_dense, 199);
        assert!(a.peak_saving() > 0.7);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = KvAccounting::new();
        a.step(5, 10);
        let mut b = KvAccounting::new();
        b.step(20, 20);
        b.compression(7);
        a.merge(&b);
        assert_eq!(a.integral_actual, 25);
        assert_eq!(a.integral_dense, 30);
        assert_eq!(a.peak_actual, 20);
        assert_eq!(a.evicted, 7);
        assert_eq!(a.steps, 2);
    }
}
