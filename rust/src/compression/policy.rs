//! Pure-Rust reference of the *positional* selection rules, used by
//! property tests to cross-check the artifact behavior and by the
//! scheduler to predict post-compression occupancy without running the
//! graph.
//!
//! Attention-score-based methods (R-KV / SnapKV / H2O) depend on the
//! model's attention values and can only be verified in-graph (pytest does
//! that against ref.py); what Rust *can* verify independently is the
//! shared selection contract:
//!   1. exactly `budget` slots survive,
//!   2. the `alpha` most recent tokens always survive,
//!   3. survivors keep their generation order,
//!   4. StreamingLLM keeps sinks + recency exactly.

/// Shared selection contract parameters.
#[derive(Debug, Clone, Copy)]
pub struct SelectParams {
    pub budget: usize,
    pub alpha: usize,
    pub sinks: usize,
}

/// Reference StreamingLLM retention over birth positions.
///
/// Input: `birth[slot]` = absolute position (all >= 0, occupied slots
/// only). Output: retained slot indices sorted by birth (ascending) —
/// sinks (oldest `sinks` positions) plus the most recent fill.
pub fn streaming_keep(birth: &[i64], p: SelectParams) -> Vec<usize> {
    let n = birth.len();
    if n <= p.budget {
        let mut all: Vec<usize> = (0..n).collect();
        all.sort_by_key(|&i| birth[i]);
        return all;
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| birth[i]);
    let mut keep: Vec<usize> = Vec::with_capacity(p.budget);
    // sinks = oldest positions
    let n_sinks = p.sinks.min(p.budget);
    keep.extend_from_slice(&order[..n_sinks]);
    // fill the rest with the most recent
    let n_recent = p.budget - n_sinks;
    keep.extend_from_slice(&order[n - n_recent..]);
    keep.sort_by_key(|&i| birth[i]);
    keep.dedup();
    keep
}

/// Check the shared selection contract over a retained set.
///
/// `birth_before[slot]` for all occupied slots, `kept` = retained slot
/// indices in compacted order. Returns Err(description) on violation.
pub fn check_contract(
    birth_before: &[i64],
    kept: &[usize],
    p: SelectParams,
) -> Result<(), String> {
    let n = birth_before.len();
    let expect = p.budget.min(n);
    if kept.len() != expect {
        return Err(format!("kept {} slots, expected {}", kept.len(), expect));
    }
    // order-preserving: birth positions strictly increase in compacted order
    for w in kept.windows(2) {
        if birth_before[w[0]] >= birth_before[w[1]] {
            return Err(format!(
                "order violated: slot {} (birth {}) before slot {} (birth {})",
                w[0], birth_before[w[0]], w[1], birth_before[w[1]]
            ));
        }
    }
    // alpha most recent must survive. Membership via a HashSet: the naive
    // `kept.contains` scan made this contract check O(alpha * budget) —
    // quadratic at the large budgets the propchecks sweep.
    let kept_set: std::collections::HashSet<usize> = kept.iter().copied().collect();
    let mut by_recency: Vec<usize> = (0..n).collect();
    by_recency.sort_by_key(|&i| std::cmp::Reverse(birth_before[i]));
    for &slot in by_recency.iter().take(p.alpha.min(expect)) {
        if !kept_set.contains(&slot) {
            return Err(format!(
                "recent slot {} (birth {}) evicted",
                slot, birth_before[slot]
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    fn params() -> SelectParams {
        SelectParams { budget: 8, alpha: 3, sinks: 2 }
    }

    #[test]
    fn streaming_keeps_sinks_and_recent() {
        let birth: Vec<i64> = (0..20).collect();
        let kept = streaming_keep(&birth, params());
        assert_eq!(kept.len(), 8);
        // sinks 0,1 plus recency 14..19
        assert_eq!(kept, vec![0, 1, 14, 15, 16, 17, 18, 19]);
    }

    #[test]
    fn streaming_underfull_keeps_all() {
        let birth: Vec<i64> = (0..5).collect();
        let kept = streaming_keep(&birth, params());
        assert_eq!(kept, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn streaming_satisfies_contract() {
        propcheck::quick("streaming-contract", |rng, size| {
            let n = 9 + size % 40;
            // random strictly increasing births (scattered positions)
            let mut birth: Vec<i64> = Vec::with_capacity(n);
            let mut cur = 0i64;
            for _ in 0..n {
                cur += 1 + rng.below(3) as i64;
                birth.push(cur);
            }
            let p = params();
            let kept = streaming_keep(&birth, p);
            check_contract(&birth, &kept, p)
        });
    }

    #[test]
    fn contract_detects_violations() {
        let birth: Vec<i64> = (0..10).collect();
        let p = SelectParams { budget: 4, alpha: 2, sinks: 1 };
        // wrong count
        assert!(check_contract(&birth, &[0, 1, 2], p).is_err());
        // out of order
        assert!(check_contract(&birth, &[0, 9, 8, 7], p).is_err());
        // missing recent
        assert!(check_contract(&birth, &[0, 1, 2, 3], p).is_err());
        // valid
        assert!(check_contract(&birth, &[0, 1, 8, 9], p).is_ok());
    }
}
