//! Compression accounting + pure-Rust reference policies.
//!
//! The *actual* cache compaction runs inside the AOT artifacts (L1/L2).
//! This module provides (a) the KV-storage accounting behind the paper's
//! "Toks. saving" column, and (b) a pure-Rust reference of the positional
//! StreamingLLM selection used by property tests to cross-check the
//! artifact's behavior (attention-score methods can only be checked
//! in-graph, which pytest does against ref.py).

pub mod accounting;
pub mod policy;

pub use accounting::KvAccounting;
