//! Checkpoint persistence for flat parameters + Adam state.
//!
//! Self-contained binary format (`.srl` files):
//!   magic "SRLCKPT1" | u32 header_len | JSON header | f32-LE params
//!   [| f32-LE m | f32-LE v]   (present when `with_opt`)
//! The JSON header records the model name, step, and counts so loads are
//! validated against the manifest before any training resumes.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{to_string, Json};

use super::engine::TrainState;

const MAGIC: &[u8; 8] = b"SRLCKPT1";

/// Save a checkpoint; `with_opt` includes the Adam moments.
pub fn save(path: &Path, model_name: &str, state: &TrainState, with_opt: bool) -> Result<()> {
    let mut header = std::collections::BTreeMap::new();
    header.insert("model".to_string(), Json::Str(model_name.to_string()));
    header.insert("step".to_string(), Json::Num(state.step as f64));
    header.insert("n_params".to_string(), Json::Num(state.params.len() as f64));
    header.insert("with_opt".to_string(), Json::Bool(with_opt));
    let header = to_string(&Json::Obj(header));

    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    write_f32s(&mut f, &state.params)?;
    if with_opt {
        write_f32s(&mut f, &state.m)?;
        write_f32s(&mut f, &state.v)?;
    }
    Ok(())
}

/// Load a checkpoint; `expect_params` validates against the manifest.
pub fn load(path: &Path, expect_params: usize) -> Result<(String, TrainState)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a sparse-rl checkpoint", path.display());
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;

    let model = header.get("model").as_str().unwrap_or("?").to_string();
    let step = header.get("step").as_i64().unwrap_or(0) as i32;
    let n = header.get("n_params").as_usize().context("n_params")?;
    let with_opt = header.get("with_opt").as_bool().unwrap_or(false);
    if n != expect_params {
        bail!(
            "{}: checkpoint has {} params, manifest expects {}",
            path.display(),
            n,
            expect_params
        );
    }
    let params = read_f32s(&mut f, n)?;
    let (m, v) = if with_opt {
        (read_f32s(&mut f, n)?, read_f32s(&mut f, n)?)
    } else {
        (vec![0.0; n], vec![0.0; n])
    };
    Ok((model, TrainState { params, m, v, step }))
}

fn write_f32s(f: &mut std::fs::File, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

fn read_f32s(f: &mut std::fs::File, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_opt() {
        let dir = std::env::temp_dir().join("srl_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.srl");
        let state = TrainState {
            params: vec![1.0, -2.5, 3.25],
            m: vec![0.1, 0.2, 0.3],
            v: vec![0.01, 0.02, 0.03],
            step: 42,
        };
        save(&path, "tiny", &state, true).unwrap();
        let (model, got) = load(&path, 3).unwrap();
        assert_eq!(model, "tiny");
        assert_eq!(got.step, 42);
        assert_eq!(got.params, state.params);
        assert_eq!(got.m, state.m);
        assert_eq!(got.v, state.v);
    }

    #[test]
    fn roundtrip_params_only() {
        let dir = std::env::temp_dir().join("srl_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.srl");
        let state = TrainState::new(vec![5.0; 7]);
        save(&path, "nano", &state, false).unwrap();
        let (_, got) = load(&path, 7).unwrap();
        assert_eq!(got.params, state.params);
        assert_eq!(got.m, vec![0.0; 7]);
    }

    #[test]
    fn wrong_size_rejected() {
        let dir = std::env::temp_dir().join("srl_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.srl");
        save(&path, "x", &TrainState::new(vec![0.0; 4]), false).unwrap();
        assert!(load(&path, 5).is_err());
    }
}
