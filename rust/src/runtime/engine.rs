//! Typed façade over one artifact directory: the `ModelEngine`.
//!
//! Owns the PJRT client, lazily compiles entry points on first use, and
//! exposes the six operations the coordinator needs (init / prefill /
//! decode / compress / score / train / lm) with plain-Rust types. All
//! shapes come from the manifest; the engine's job is marshalling and
//! invariant checks, never shape arithmetic.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::executable::Executable;
use super::manifest::Manifest;
use super::tensor::HostTensor;

/// Dense (full cache) vs sparse (budget-compressed cache) rollout path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Dense,
    Sparse,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Dense => "dense",
            Variant::Sparse => "sparse",
        }
    }
}

/// KV compression method (paper §2 / Appendix A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    RKv,
    SnapKv,
    H2O,
    Streaming,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::RKv => "rkv",
            Method::SnapKv => "snapkv",
            Method::H2O => "h2o",
            Method::Streaming => "streaming",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "rkv" | "r-kv" => Method::RKv,
            "snapkv" | "snap-kv" => Method::SnapKv,
            "h2o" => Method::H2O,
            "streaming" | "streamingllm" => Method::Streaming,
            other => bail!("unknown compression method {other:?}"),
        })
    }

    pub fn all() -> [Method; 4] {
        [Method::RKv, Method::SnapKv, Method::H2O, Method::Streaming]
    }
}

/// Device-shaped KV cache state for one decode batch.
///
/// Layout mirrors the artifacts: kv [L,2,R,H,C,Dh] f32, stats [L,R,H,C]
/// f32, birth [L,R,H,C] i32. `lens` (occupied slots) and `pos` (absolute
/// positions) live with the rollout engine, not here, because they advance
/// per-sequence on the Rust side.
///
/// State tensors are kept as XLA literals between steps (hot-path
/// optimization: they re-enter the next decode exactly as the previous
/// call produced them, with no HostTensor round-trip — §Perf).
pub struct CacheState {
    pub kv: xla::Literal,
    pub stats_cum: xla::Literal,
    pub stats_win: xla::Literal,
    pub birth: xla::Literal,
    pub capacity: usize,
    pub variant: Variant,
}

/// Model weights uploaded once per rollout chunk (not per decode step).
pub struct ParamsLit(xla::Literal);

impl ParamsLit {
    pub fn new(params: &[f32]) -> ParamsLit {
        ParamsLit(xla::Literal::vec1(params))
    }
}

/// Learner weights + Adam state (flat, matching the manifest layout).
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i32,
}

impl TrainState {
    pub fn new(params: Vec<f32>) -> Self {
        let n = params.len();
        TrainState { params, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}

/// Scalar statistics returned by one RL train step.
#[derive(Debug, Clone, Copy, Default)]
pub struct TrainStats {
    pub loss: f64,
    pub grad_norm: f64,
    pub clip_frac: f64,
    pub entropy: f64,
    pub kl: f64,
}

/// RL hyper-parameters fed to the train artifact (runtime inputs, so
/// sweeps don't need recompilation).
#[derive(Debug, Clone, Copy)]
pub struct Hyp {
    pub lr: f32,
    pub clip_eps: f32,
    pub kl_coef: f32,
    pub max_grad_norm: f32,
}

impl Default for Hyp {
    fn default() -> Self {
        // Paper §5.1: lr 1e-6, KL coef 1e-4. Scaled for our from-scratch
        // small models: lr 1e-4; KL 1e-3 anchors the weak base against
        // drift under sparse binary rewards (tuning log in EXPERIMENTS.md).
        Hyp { lr: 1e-4, clip_eps: 0.2, kl_coef: 1e-3, max_grad_norm: 1.0 }
    }
}

impl Hyp {
    fn tensor(&self) -> HostTensor {
        HostTensor::f32(
            vec![self.lr, self.clip_eps, self.kl_coef, self.max_grad_norm],
            &[4],
        )
    }
}

/// The engine: client + manifest + lazily compiled entry points.
///
/// `ModelEngine` is `Sync`: the executable cache sits behind a mutex and
/// per-entry latency counters are atomics, so the pipelined rollout
/// engine's worker threads can each drive their own `EngineBackend` over
/// one shared `&ModelEngine`. (Whether concurrent *execution* actually
/// parallelizes is the runtime's business — the vendored offline stub
/// errors on execution either way, and a real PJRT client serializes or
/// parallelizes internally.)
pub struct ModelEngine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl ModelEngine {
    /// Open an artifact directory (compiles nothing yet).
    pub fn load(dir: &Path) -> Result<ModelEngine> {
        let manifest = Manifest::load(dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(ModelEngine { client, manifest, exes: Mutex::new(BTreeMap::new()) })
    }

    /// Get (compiling on first use) an entry point by name. The cache
    /// lock is held across a first-use compile — a deliberate choice:
    /// racing workers would otherwise compile the same entry twice.
    pub fn exe(&self, name: &str) -> Result<Arc<Executable>> {
        let mut exes = self
            .exes
            .lock()
            .map_err(|_| anyhow::anyhow!("executable cache poisoned"))?;
        if let Some(e) = exes.get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.entry(name)?;
        let exe = Arc::new(Executable::load(&self.client, spec)?);
        exes.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile a set of entry points (startup cost, not hot path).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.exe(n)?;
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // typed operations
    // ---------------------------------------------------------------

    /// Deterministic parameter init (same bits as pytest's jax init).
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let out = self.exe("init_params")?.run(&[HostTensor::scalar_i32(seed)])?;
        Ok(out.into_iter().next().unwrap().as_f32()?.to_vec())
    }

    /// Fresh all-zero cache of the variant's capacity (tests/benches; the
    /// rollout path gets its cache from `prefill`).
    pub fn empty_cache(&self, variant: Variant) -> CacheState {
        let c = &self.manifest.config;
        let s = &self.manifest.shapes;
        let cap = match variant {
            Variant::Dense => s.dense_capacity,
            Variant::Sparse => s.sparse_capacity,
        };
        let (l, r, h, dh) = (c.n_layers, s.decode_batch, c.n_heads, c.d_head);
        let lit = |t: HostTensor| t.to_literal().expect("literal");
        CacheState {
            kv: lit(HostTensor::zeros_f32(&[l, 2, r, h, cap, dh])),
            stats_cum: lit(HostTensor::zeros_f32(&[l, r, h, cap])),
            stats_win: lit(HostTensor::zeros_f32(&[l, r, h, cap])),
            birth: lit(HostTensor::i32(vec![-1; l * r * h * cap], &[l, r, h, cap])),
            capacity: cap,
            variant,
        }
    }

    /// Prefill the prompt batch; returns the cache and last-token log-probs
    /// [R, V] flattened.
    pub fn prefill(
        &self,
        variant: Variant,
        params: &ParamsLit,
        ids: &[i32],
        lens: &[i32],
    ) -> Result<(CacheState, Vec<f32>)> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let name = format!("prefill_{}", variant.name());
        let exe = self.exe(&name)?;
        let ids_l = HostTensor::i32(ids.to_vec(), &[s.decode_batch, c.prompt_len]).to_literal()?;
        let lens_l = HostTensor::i32(lens.to_vec(), &[s.decode_batch]).to_literal()?;
        let out = exe.run_literals(&[&params.0, &ids_l, &lens_l])?;
        let mut it = out.into_iter();
        let kv = it.next().unwrap();
        let stats_cum = it.next().unwrap();
        let stats_win = it.next().unwrap();
        let birth = it.next().unwrap();
        let logp = it
            .next()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("prefill logp: {e:?}"))?;
        let cap = match variant {
            Variant::Dense => s.dense_capacity,
            Variant::Sparse => s.sparse_capacity,
        };
        Ok((CacheState { kv, stats_cum, stats_win, birth, capacity: cap, variant }, logp))
    }

    /// Prefill ONE slot of a live cache in place, leaving every other
    /// slot's state untouched (continuous batching's slot recycling).
    /// Returns the slot's last-prompt-token log-probs `[V]`.
    ///
    /// The AOT artifact set only ships a full-batch prefill, so this runs
    /// it on a scratch batch carrying `prompt` in the target slot and
    /// splices that slot's planes (kv / stats / birth) into `cache` with a
    /// host round-trip. Correctness rests on batch-row independence: a
    /// slot's prefill output is bit-identical regardless of what occupies
    /// the other rows (each row attends only to its own cache), which the
    /// artifact-gated integration tests assert. The round-trip copies the
    /// whole cache through host memory — acceptable for correctness-first;
    /// a fused dynamic-update-slice prefill entry is a ROADMAP follow-up.
    pub fn prefill_slot(
        &self,
        params: &ParamsLit,
        cache: &mut CacheState,
        slot: usize,
        prompt: &[i32],
    ) -> Result<Vec<f32>> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let (r, p_len, vocab) = (s.decode_batch, c.prompt_len, c.vocab);
        if slot >= r {
            bail!("prefill_slot: slot {slot} out of range (R = {r})");
        }
        if prompt.is_empty() || prompt.len() > p_len {
            bail!("prefill_slot: prompt length {} not in 1..={p_len}", prompt.len());
        }
        // Scratch batch: the prompt in the target slot; other rows hold a
        // minimal valid row (their planes are discarded by the splice, and
        // row independence means their content cannot leak into ours).
        let mut ids = vec![prompt[0]; r * p_len];
        let mut plens = vec![1i32; r];
        ids[slot * p_len..slot * p_len + prompt.len()].copy_from_slice(prompt);
        plens[slot] = prompt.len() as i32;
        let (fresh, logp) = self.prefill(cache.variant, params, &ids, &plens)?;

        // Splice the target slot's planes from the fresh cache into the
        // live one. Layouts (slot axis = R): kv [L,2,R,H,C,Dh],
        // stats/birth [L,R,H,C].
        let (l, h, dh, cap) = (c.n_layers, c.n_heads, c.d_head, cache.capacity);
        splice_f32(&mut cache.kv, &fresh.kv, l * 2, r, h * cap * dh, slot,
            &[l, 2, r, h, cap, dh])?;
        splice_f32(&mut cache.stats_cum, &fresh.stats_cum, l, r, h * cap, slot,
            &[l, r, h, cap])?;
        splice_f32(&mut cache.stats_win, &fresh.stats_win, l, r, h * cap, slot,
            &[l, r, h, cap])?;
        splice_i32(&mut cache.birth, &fresh.birth, l, r, h * cap, slot,
            &[l, r, h, cap])?;
        Ok(logp[slot * vocab..(slot + 1) * vocab].to_vec())
    }

    /// One decode step over the batch; returns log-probs [R, V] flattened
    /// and replaces the cache state in place. This is THE hot path: the
    /// cache literals flow straight back in, and only the small control
    /// vectors (lens/pos/token) are fresh allocations.
    pub fn decode(
        &self,
        params: &ParamsLit,
        cache: &mut CacheState,
        lens: &[i32],
        pos: &[i32],
        token: &[i32],
    ) -> Result<Vec<f32>> {
        let s = &self.manifest.shapes;
        let name = format!("decode_{}", cache.variant.name());
        let exe = self.exe(&name)?;
        let r = s.decode_batch;
        let lens_l = HostTensor::i32(lens.to_vec(), &[r]).to_literal()?;
        let pos_l = HostTensor::i32(pos.to_vec(), &[r]).to_literal()?;
        let tok_l = HostTensor::i32(token.to_vec(), &[r]).to_literal()?;
        let out = exe.run_literals(&[
            &params.0,
            &cache.kv,
            &cache.stats_cum,
            &cache.stats_win,
            &cache.birth,
            &lens_l,
            &pos_l,
            &tok_l,
        ])?;
        let mut it = out.into_iter();
        let logp = it
            .next()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("decode logp: {e:?}"))?;
        cache.kv = it.next().unwrap();
        cache.stats_cum = it.next().unwrap();
        cache.stats_win = it.next().unwrap();
        cache.birth = it.next().unwrap();
        Ok(logp)
    }

    /// Compress the sequences with `do_mask[b] = 1.0` down to the budget.
    pub fn compress(
        &self,
        method: Method,
        cache: &mut CacheState,
        do_mask: &[f32],
    ) -> Result<()> {
        if cache.variant != Variant::Sparse {
            bail!("compress called on a dense cache");
        }
        let s = &self.manifest.shapes;
        let name = format!("compress_{}", method.name());
        let exe = self.exe(&name)?;
        let do_l = HostTensor::f32(do_mask.to_vec(), &[s.decode_batch]).to_literal()?;
        let out = exe.run_literals(&[
            &cache.kv,
            &cache.stats_cum,
            &cache.stats_win,
            &cache.birth,
            &do_l,
        ])?;
        let mut it = out.into_iter();
        cache.kv = it.next().unwrap();
        cache.stats_cum = it.next().unwrap();
        cache.stats_win = it.next().unwrap();
        cache.birth = it.next().unwrap();
        Ok(())
    }

    /// Dense teacher-forcing scores: per-token log π(ids[t] | ids[<t]) and
    /// predictive entropy, both [Btr, T] flattened.
    pub fn score(
        &self,
        params: &[f32],
        ids: &[i32],
        lens: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let exe = self.exe("score")?;
        let out = exe.run(&[
            HostTensor::f32(params.to_vec(), &[c.n_params]),
            HostTensor::i32(ids.to_vec(), &[s.train_batch, c.max_seq]),
            HostTensor::i32(lens.to_vec(), &[s.train_batch]),
        ])?;
        let mut it = out.into_iter();
        let logp = it.next().unwrap().as_f32()?.to_vec();
        let ent = it.next().unwrap().as_f32()?.to_vec();
        Ok((logp, ent))
    }

    /// Inputs for one RL train step over [Btr, T].
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &self,
        state: &mut TrainState,
        ids: &[i32],
        loss_mask: &[f32],
        lens: &[i32],
        adv: &[f32],
        xi: &[f32],
        mrs: &[f32],
        logp_old: &[f32],
        hyp: Hyp,
    ) -> Result<TrainStats> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let exe = self.exe("train")?;
        let (b, t, n) = (s.train_batch, c.max_seq, c.n_params);
        let out = exe.run(&[
            HostTensor::f32(std::mem::take(&mut state.params), &[n]),
            HostTensor::f32(std::mem::take(&mut state.m), &[n]),
            HostTensor::f32(std::mem::take(&mut state.v), &[n]),
            HostTensor::scalar_i32(state.step),
            HostTensor::i32(ids.to_vec(), &[b, t]),
            HostTensor::f32(loss_mask.to_vec(), &[b, t]),
            HostTensor::i32(lens.to_vec(), &[b]),
            HostTensor::f32(adv.to_vec(), &[b]),
            HostTensor::f32(xi.to_vec(), &[b, t]),
            HostTensor::f32(mrs.to_vec(), &[b]),
            HostTensor::f32(logp_old.to_vec(), &[b, t]),
            hyp.tensor(),
        ])?;
        let mut it = out.into_iter();
        state.params = it.next().unwrap().as_f32()?.to_vec();
        state.m = it.next().unwrap().as_f32()?.to_vec();
        state.v = it.next().unwrap().as_f32()?.to_vec();
        state.step = it.next().unwrap().as_i32()?[0];
        Ok(TrainStats {
            loss: it.next().unwrap().scalar()?,
            grad_norm: it.next().unwrap().scalar()?,
            clip_frac: it.next().unwrap().scalar()?,
            entropy: it.next().unwrap().scalar()?,
            kl: it.next().unwrap().scalar()?,
        })
    }

    /// One supervised LM (pretraining) step; returns the CE loss.
    pub fn lm(
        &self,
        state: &mut TrainState,
        ids: &[i32],
        mask: &[f32],
        lens: &[i32],
        hyp: Hyp,
    ) -> Result<f64> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let exe = self.exe("lm")?;
        let (b, t, n) = (s.train_batch, c.max_seq, c.n_params);
        let out = exe.run(&[
            HostTensor::f32(std::mem::take(&mut state.params), &[n]),
            HostTensor::f32(std::mem::take(&mut state.m), &[n]),
            HostTensor::f32(std::mem::take(&mut state.v), &[n]),
            HostTensor::scalar_i32(state.step),
            HostTensor::i32(ids.to_vec(), &[b, t]),
            HostTensor::f32(mask.to_vec(), &[b, t]),
            HostTensor::i32(lens.to_vec(), &[b]),
            hyp.tensor(),
        ])?;
        let mut it = out.into_iter();
        state.params = it.next().unwrap().as_f32()?.to_vec();
        state.m = it.next().unwrap().as_f32()?.to_vec();
        state.v = it.next().unwrap().as_f32()?.to_vec();
        state.step = it.next().unwrap().as_i32()?[0];
        it.next().unwrap().scalar()
    }

    /// Per-entry mean latency report (perf instrumentation).
    pub fn latency_report(&self) -> Vec<(String, u64, f64)> {
        self.exes
            .lock()
            .map(|exes| {
                exes.iter()
                    .map(|(n, e)| (n.clone(), e.calls(), e.mean_latency_ns()))
                    .collect()
            })
            .unwrap_or_default()
    }
}

/// Copy slot `slot`'s plane from `src` into `dst` for a tensor whose
/// row-major layout is [outer.., R, plane..]: `outer` leading blocks, each
/// holding R slot planes of `plane` elements (the slot axis of every cache
/// tensor). Host round-trip; see `prefill_slot`. One macro-generated body
/// per element type so the bounds/copy logic cannot drift between the f32
/// (kv/stats) and i32 (birth) variants.
macro_rules! splice_plane {
    ($name:ident, $ty:ty) => {
        fn $name(
            dst: &mut xla::Literal,
            src: &xla::Literal,
            outer: usize,
            r: usize,
            plane: usize,
            slot: usize,
            dims: &[usize],
        ) -> Result<()> {
            let mut d = dst
                .to_vec::<$ty>()
                .map_err(|e| anyhow::anyhow!("splice dst: {e:?}"))?;
            let s = src
                .to_vec::<$ty>()
                .map_err(|e| anyhow::anyhow!("splice src: {e:?}"))?;
            if d.len() != s.len() || d.len() != outer * r * plane {
                bail!(
                    "splice: layout mismatch (dst {}, src {}, expect {})",
                    d.len(),
                    s.len(),
                    outer * r * plane
                );
            }
            for o in 0..outer {
                let base = (o * r + slot) * plane;
                d[base..base + plane].copy_from_slice(&s[base..base + plane]);
            }
            let dims_i64: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
            *dst = xla::Literal::vec1(&d).reshape(&dims_i64)?;
            Ok(())
        }
    };
}

splice_plane!(splice_f32, f32);
splice_plane!(splice_i32, i32);
