//! Typed façade over one artifact directory: the `ModelEngine`.
//!
//! Owns the PJRT client, lazily compiles entry points on first use, and
//! exposes the six operations the coordinator needs (init / prefill /
//! decode / compress / score / train / lm) with plain-Rust types. All
//! shapes come from the manifest; the engine's job is marshalling and
//! invariant checks, never shape arithmetic.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::executable::Executable;
use super::manifest::Manifest;
use super::tensor::HostTensor;

/// Dense (full cache) vs sparse (budget-compressed cache) rollout path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Dense,
    Sparse,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Dense => "dense",
            Variant::Sparse => "sparse",
        }
    }
}

/// KV compression method (paper §2 / Appendix A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    RKv,
    SnapKv,
    H2O,
    Streaming,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::RKv => "rkv",
            Method::SnapKv => "snapkv",
            Method::H2O => "h2o",
            Method::Streaming => "streaming",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "rkv" | "r-kv" => Method::RKv,
            "snapkv" | "snap-kv" => Method::SnapKv,
            "h2o" => Method::H2O,
            "streaming" | "streamingllm" => Method::Streaming,
            other => bail!("unknown compression method {other:?}"),
        })
    }

    pub fn all() -> [Method; 4] {
        [Method::RKv, Method::SnapKv, Method::H2O, Method::Streaming]
    }
}

/// Device-shaped KV cache state for one decode batch.
///
/// Layout mirrors the artifacts: kv [L,2,R,H,C,Dh] f32, stats [L,R,H,C]
/// f32, birth [L,R,H,C] i32. `lens` (occupied slots) and `pos` (absolute
/// positions) live with the rollout engine, not here, because they advance
/// per-sequence on the Rust side.
///
/// State tensors are kept as XLA literals between steps (hot-path
/// optimization: they re-enter the next decode exactly as the previous
/// call produced them, with no HostTensor round-trip — §Perf).
pub struct CacheState {
    pub kv: xla::Literal,
    pub stats_cum: xla::Literal,
    pub stats_win: xla::Literal,
    pub birth: xla::Literal,
    pub capacity: usize,
    pub variant: Variant,
}

/// Model weights uploaded once per rollout chunk (not per decode step).
pub struct ParamsLit(xla::Literal);

impl ParamsLit {
    pub fn new(params: &[f32]) -> ParamsLit {
        ParamsLit(xla::Literal::vec1(params))
    }
}

/// Learner weights + Adam state (flat, matching the manifest layout).
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i32,
}

impl TrainState {
    pub fn new(params: Vec<f32>) -> Self {
        let n = params.len();
        TrainState { params, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}

/// Scalar statistics returned by one RL train step.
#[derive(Debug, Clone, Copy, Default)]
pub struct TrainStats {
    pub loss: f64,
    pub grad_norm: f64,
    pub clip_frac: f64,
    pub entropy: f64,
    pub kl: f64,
}

/// RL hyper-parameters fed to the train artifact (runtime inputs, so
/// sweeps don't need recompilation).
#[derive(Debug, Clone, Copy)]
pub struct Hyp {
    pub lr: f32,
    pub clip_eps: f32,
    pub kl_coef: f32,
    pub max_grad_norm: f32,
}

impl Default for Hyp {
    fn default() -> Self {
        // Paper §5.1: lr 1e-6, KL coef 1e-4. Scaled for our from-scratch
        // small models: lr 1e-4; KL 1e-3 anchors the weak base against
        // drift under sparse binary rewards (tuning log in EXPERIMENTS.md).
        Hyp { lr: 1e-4, clip_eps: 0.2, kl_coef: 1e-3, max_grad_norm: 1.0 }
    }
}

impl Hyp {
    fn tensor(&self) -> HostTensor {
        HostTensor::f32(
            vec![self.lr, self.clip_eps, self.kl_coef, self.max_grad_norm],
            &[4],
        )
    }
}

/// The engine: client + manifest + lazily compiled entry points.
///
/// `ModelEngine` is `Sync`: the executable cache sits behind a mutex and
/// per-entry latency counters are atomics, so the pipelined rollout
/// engine's worker threads can each drive their own `EngineBackend` over
/// one shared `&ModelEngine`. (Whether concurrent *execution* actually
/// parallelizes is the runtime's business — the vendored offline stub
/// errors on execution either way, and a real PJRT client serializes or
/// parallelizes internally.)
pub struct ModelEngine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl ModelEngine {
    /// Open an artifact directory (compiles nothing yet).
    pub fn load(dir: &Path) -> Result<ModelEngine> {
        let manifest = Manifest::load(dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(ModelEngine { client, manifest, exes: Mutex::new(BTreeMap::new()) })
    }

    /// Get (compiling on first use) an entry point by name. The cache
    /// lock is held across a first-use compile — a deliberate choice:
    /// racing workers would otherwise compile the same entry twice.
    pub fn exe(&self, name: &str) -> Result<Arc<Executable>> {
        let mut exes = self
            .exes
            .lock()
            .map_err(|_| anyhow::anyhow!("executable cache poisoned"))?;
        if let Some(e) = exes.get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.entry(name)?;
        let exe = Arc::new(Executable::load(&self.client, spec)?);
        exes.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile a set of entry points (startup cost, not hot path).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.exe(n)?;
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // typed operations
    // ---------------------------------------------------------------

    /// Deterministic parameter init (same bits as pytest's jax init).
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let out = self.exe("init_params")?.run(&[HostTensor::scalar_i32(seed)])?;
        Ok(out.into_iter().next().unwrap().as_f32()?.to_vec())
    }

    /// Fresh all-zero cache of the variant's capacity (tests/benches; the
    /// rollout path gets its cache from `prefill`).
    pub fn empty_cache(&self, variant: Variant) -> CacheState {
        let c = &self.manifest.config;
        let s = &self.manifest.shapes;
        let cap = match variant {
            Variant::Dense => s.dense_capacity,
            Variant::Sparse => s.sparse_capacity,
        };
        let (l, r, h, dh) = (c.n_layers, s.decode_batch, c.n_heads, c.d_head);
        let lit = |t: HostTensor| t.to_literal().expect("literal");
        CacheState {
            kv: lit(HostTensor::zeros_f32(&[l, 2, r, h, cap, dh])),
            stats_cum: lit(HostTensor::zeros_f32(&[l, r, h, cap])),
            stats_win: lit(HostTensor::zeros_f32(&[l, r, h, cap])),
            birth: lit(HostTensor::i32(vec![-1; l * r * h * cap], &[l, r, h, cap])),
            capacity: cap,
            variant,
        }
    }

    /// Prefill the prompt batch; returns the cache and last-token log-probs
    /// [R, V] flattened.
    pub fn prefill(
        &self,
        variant: Variant,
        params: &ParamsLit,
        ids: &[i32],
        lens: &[i32],
    ) -> Result<(CacheState, Vec<f32>)> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let name = format!("prefill_{}", variant.name());
        let exe = self.exe(&name)?;
        let ids_l = HostTensor::i32(ids.to_vec(), &[s.decode_batch, c.prompt_len]).to_literal()?;
        let lens_l = HostTensor::i32(lens.to_vec(), &[s.decode_batch]).to_literal()?;
        let out = exe.run_literals(&[&params.0, &ids_l, &lens_l])?;
        let mut it = out.into_iter();
        let kv = it.next().unwrap();
        let stats_cum = it.next().unwrap();
        let stats_win = it.next().unwrap();
        let birth = it.next().unwrap();
        let logp = it
            .next()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("prefill logp: {e:?}"))?;
        let cap = match variant {
            Variant::Dense => s.dense_capacity,
            Variant::Sparse => s.sparse_capacity,
        };
        Ok((CacheState { kv, stats_cum, stats_win, birth, capacity: cap, variant }, logp))
    }

    /// Prefill ONE slot of a live cache in place, leaving every other
    /// slot's state untouched (continuous batching's slot recycling).
    /// Returns the slot's last-prompt-token log-probs `[V]`.
    ///
    /// Two implementations, selected by the manifest:
    ///
    /// * **Fused** (`prefill_slot_<variant>` entry present): one device
    ///   call takes the live cache, a slot mask, and the scratch prompt
    ///   batch, and writes the slot's planes in-graph (a masked
    ///   dynamic-update-slice-style select on the slot axis) — no host
    ///   round-trip at all.
    /// * **Fallback** (older artifact sets): a scratch-batch prefill plus
    ///   a host-side plane splice (`prepare_slot_prefill` +
    ///   `splice_slot`). Correctness rests on batch-row independence: a
    ///   slot's prefill output is bit-identical regardless of what
    ///   occupies the other rows (each row attends only to its own
    ///   cache), which the artifact-gated integration tests assert.
    pub fn prefill_slot(
        &self,
        params: &ParamsLit,
        cache: &mut CacheState,
        slot: usize,
        prompt: &[i32],
    ) -> Result<Vec<f32>> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let (r, p_len) = (s.decode_batch, c.prompt_len);
        if slot >= r {
            bail!("prefill_slot: slot {slot} out of range (R = {r})");
        }
        if prompt.is_empty() || prompt.len() > p_len {
            bail!("prefill_slot: prompt length {} not in 1..={p_len}", prompt.len());
        }
        let entry = fused_prefill_entry(cache.variant);
        if self.manifest.has_entry(&entry) {
            return self.prefill_slot_fused(&entry, params, cache, slot, prompt);
        }
        let (fresh, logp) = self.prepare_slot_prefill(params, cache.variant, prompt)?;
        self.splice_slot(cache, &fresh, 0, slot)?;
        Ok(logp)
    }

    /// Fused slot-masked prefill: the whole recycling write is one device
    /// call on the `prefill_slot_<variant>` entry — the live cache flows
    /// in as literals, the entry prefills the scratch prompt batch and
    /// selects the masked slot's fresh planes in-graph, and the updated
    /// cache flows straight back out. No host copies of any cache plane.
    fn prefill_slot_fused(
        &self,
        entry: &str,
        params: &ParamsLit,
        cache: &mut CacheState,
        slot: usize,
        prompt: &[i32],
    ) -> Result<Vec<f32>> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let (r, p_len, vocab) = (s.decode_batch, c.prompt_len, c.vocab);
        let (ids, plens) = scratch_prompt_batch(r, p_len, slot, prompt);
        let mut mask = vec![0.0f32; r];
        mask[slot] = 1.0;
        let exe = self.exe(entry)?;
        let ids_l = HostTensor::i32(ids, &[r, p_len]).to_literal()?;
        let lens_l = HostTensor::i32(plens, &[r]).to_literal()?;
        let mask_l = HostTensor::f32(mask, &[r]).to_literal()?;
        let out = exe.run_literals(&[
            &params.0,
            &cache.kv,
            &cache.stats_cum,
            &cache.stats_win,
            &cache.birth,
            &ids_l,
            &lens_l,
            &mask_l,
        ])?;
        let mut it = out.into_iter();
        cache.kv = it.next().unwrap();
        cache.stats_cum = it.next().unwrap();
        cache.stats_win = it.next().unwrap();
        cache.birth = it.next().unwrap();
        let logp = it
            .next()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("prefill_slot_fused logp: {e:?}"))?;
        Ok(logp[slot * vocab..(slot + 1) * vocab].to_vec())
    }

    /// Prefill tokens `[start, start + chunk)` of `prompt` into `slot` of
    /// a live cache, every other slot untouched — the resumable form of
    /// `prefill_slot` behind the token-budgeted step packer
    /// (`prefill-chunk-tokens`). `start` must equal the number of prompt
    /// tokens already written to the slot (`start == 0` begins a fresh
    /// slot). Returns `Some(logits [V])` — bit-identical to a monolithic
    /// `prefill_slot(slot, prompt)` — exactly when `start + chunk`
    /// reaches the prompt end, `None` for an intermediate chunk.
    ///
    /// Two implementations, selected by the manifest:
    ///
    /// * **Fused** (`prefill_chunk_<variant>` entry present): one device
    ///   call takes the live cache, the scratch prompt batch, per-row
    ///   `[start, limit)` ranges and a slot mask. The entry recomputes
    ///   the grown prefix's activations and writes only the fresh
    ///   KV/birth range plus whole-prefix stats in-graph (stats colsum
    ///   over later query rows, so they are rewritten — not accumulated —
    ///   each chunk; the final chunk leaves them exactly monolithic).
    /// * **Fallback** (older artifact sets without the entry): chunking
    ///   degrades instead of breaking — intermediate chunks defer all
    ///   device work and the final chunk delegates to `prefill_slot`
    ///   over the whole prompt, which is token-identical. The packer's
    ///   modeled cost still uses chunked accounting; only the shape of
    ///   the device calls differs.
    pub fn prefill_chunk(
        &self,
        params: &ParamsLit,
        cache: &mut CacheState,
        slot: usize,
        prompt: &[i32],
        start: usize,
        chunk: usize,
    ) -> Result<Option<Vec<f32>>> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let (r, p_len) = (s.decode_batch, c.prompt_len);
        if slot >= r {
            bail!("prefill_chunk: slot {slot} out of range (R = {r})");
        }
        if prompt.is_empty() || prompt.len() > p_len {
            bail!("prefill_chunk: prompt length {} not in 1..={p_len}", prompt.len());
        }
        if chunk == 0 || start + chunk > prompt.len() {
            bail!(
                "prefill_chunk: range [{start}, {}) invalid for prompt length {}",
                start + chunk,
                prompt.len()
            );
        }
        let done = start + chunk == prompt.len();
        let entry = chunk_prefill_entry(cache.variant);
        if self.manifest.has_entry(&entry) {
            let logp =
                self.prefill_chunk_fused(&entry, params, cache, slot, prompt, start, chunk)?;
            return Ok(if done { Some(logp) } else { None });
        }
        if done {
            return self.prefill_slot(params, cache, slot, prompt).map(Some);
        }
        Ok(None)
    }

    /// Fused partial-range prefill: one device call on the
    /// `prefill_chunk_<variant>` entry. The scratch batch carries the
    /// WHOLE prompt prefix seen so far (positions `< start + chunk`) —
    /// the entry re-attends over it causally, exactly as the monolithic
    /// prefill would, and the per-row `[start, limit)` range restricts
    /// the KV/birth writes to the fresh tokens so earlier chunks' planes
    /// are preserved bit-for-bit. Returns the slot's logits row at the
    /// last visible token (only meaningful to the caller on the final
    /// chunk).
    #[allow(clippy::too_many_arguments)]
    fn prefill_chunk_fused(
        &self,
        entry: &str,
        params: &ParamsLit,
        cache: &mut CacheState,
        slot: usize,
        prompt: &[i32],
        start: usize,
        chunk: usize,
    ) -> Result<Vec<f32>> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let (r, p_len, vocab) = (s.decode_batch, c.prompt_len, c.vocab);
        let (ids, plens) = scratch_prompt_batch(r, p_len, slot, prompt);
        let mut mask = vec![0.0f32; r];
        mask[slot] = 1.0;
        // Filler rows get the degenerate range [0, 1): a single-token
        // "fresh" write whose planes the slot mask discards anyway.
        let mut starts = vec![0i32; r];
        let mut limits = vec![1i32; r];
        starts[slot] = start as i32;
        limits[slot] = (start + chunk) as i32;
        let exe = self.exe(entry)?;
        let ids_l = HostTensor::i32(ids, &[r, p_len]).to_literal()?;
        let lens_l = HostTensor::i32(plens, &[r]).to_literal()?;
        let start_l = HostTensor::i32(starts, &[r]).to_literal()?;
        let limit_l = HostTensor::i32(limits, &[r]).to_literal()?;
        let mask_l = HostTensor::f32(mask, &[r]).to_literal()?;
        let out = exe.run_literals(&[
            &params.0,
            &cache.kv,
            &cache.stats_cum,
            &cache.stats_win,
            &cache.birth,
            &ids_l,
            &lens_l,
            &start_l,
            &limit_l,
            &mask_l,
        ])?;
        let mut it = out.into_iter();
        cache.kv = it.next().unwrap();
        cache.stats_cum = it.next().unwrap();
        cache.stats_win = it.next().unwrap();
        cache.birth = it.next().unwrap();
        let logp = it
            .next()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("prefill_chunk_fused logp: {e:?}"))?;
        Ok(logp[slot * vocab..(slot + 1) * vocab].to_vec())
    }

    /// Cache-independent half of a slot prefill: run the batched prefill
    /// on a scratch batch carrying `prompt` in ROW 0 and return the fresh
    /// cache plus row 0's last-prompt-token log-probs `[V]`.
    ///
    /// Batch-row independence makes row 0's planes identical to what the
    /// prompt would produce in any slot, so `splice_slot` can land them
    /// anywhere. Crucially, this touches no live rollout state — it is
    /// what the async prefill executor runs on its own backend, off the
    /// decode workers, while they keep decoding.
    pub fn prepare_slot_prefill(
        &self,
        params: &ParamsLit,
        variant: Variant,
        prompt: &[i32],
    ) -> Result<(CacheState, Vec<f32>)> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let (r, p_len, vocab) = (s.decode_batch, c.prompt_len, c.vocab);
        if prompt.is_empty() || prompt.len() > p_len {
            bail!(
                "prepare_slot_prefill: prompt length {} not in 1..={p_len}",
                prompt.len()
            );
        }
        let (ids, plens) = scratch_prompt_batch(r, p_len, 0, prompt);
        let (fresh, logp) = self.prefill(variant, params, &ids, &plens)?;
        Ok((fresh, logp[..vocab].to_vec()))
    }

    /// Extract `slot`'s cache planes from `cache` into a compact
    /// [`SlotPlanes`] (host round-trip). Together with `implant_slot`
    /// this is the transferable form of one slot's state: the async
    /// prefill executor ships exactly one slot's planes to the owning
    /// worker instead of a whole R-slot scratch cache (1/R-th of the
    /// bytes held per in-flight prefill).
    pub fn extract_slot(&self, cache: &CacheState, slot: usize) -> Result<SlotPlanes> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let (l, r, h, dh) = (c.n_layers, s.decode_batch, c.n_heads, c.d_head);
        let cap = cache.capacity;
        if slot >= r {
            bail!("extract_slot: slot {slot} out of range (R = {r})");
        }
        Ok(SlotPlanes {
            kv: extract_f32(&cache.kv, l * 2, r, h * cap * dh, slot)?,
            stats_cum: extract_f32(&cache.stats_cum, l, r, h * cap, slot)?,
            stats_win: extract_f32(&cache.stats_win, l, r, h * cap, slot)?,
            birth: extract_i32(&cache.birth, l, r, h * cap, slot)?,
            capacity: cap,
            variant: cache.variant,
        })
    }

    /// Write compact `planes` into `slot` of `cache` (host round-trip) —
    /// the adjoint of `extract_slot`: implanting what `extract_slot`
    /// read leaves the slot exactly as a `splice_slot` from the source
    /// cache would (unit-tested below; the async apply path relies on
    /// it).
    pub fn implant_slot(
        &self,
        cache: &mut CacheState,
        slot: usize,
        planes: &SlotPlanes,
    ) -> Result<()> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let (l, r, h, dh) = (c.n_layers, s.decode_batch, c.n_heads, c.d_head);
        let cap = cache.capacity;
        if planes.capacity != cap || planes.variant != cache.variant {
            bail!(
                "implant_slot: plane mismatch ({:?}/{} vs {:?}/{})",
                planes.variant,
                planes.capacity,
                cache.variant,
                cap
            );
        }
        if slot >= r {
            bail!("implant_slot: slot {slot} out of range (R = {r})");
        }
        implant_f32(&mut cache.kv, &planes.kv, l * 2, r, h * cap * dh, slot,
            &[l, 2, r, h, cap, dh])?;
        implant_f32(&mut cache.stats_cum, &planes.stats_cum, l, r, h * cap, slot,
            &[l, r, h, cap])?;
        implant_f32(&mut cache.stats_win, &planes.stats_win, l, r, h * cap, slot,
            &[l, r, h, cap])?;
        implant_i32(&mut cache.birth, &planes.birth, l, r, h * cap, slot,
            &[l, r, h, cap])?;
        Ok(())
    }

    /// Copy `src_slot`'s cache planes (kv / stats / birth) from `src`
    /// into `dst_slot` of `dst` through a host round-trip — the portable
    /// slot write behind the non-fused `prefill_slot` fallback. Layouts
    /// (slot axis = R): kv [L,2,R,H,C,Dh], stats/birth [L,R,H,C].
    pub fn splice_slot(
        &self,
        dst: &mut CacheState,
        src: &CacheState,
        src_slot: usize,
        dst_slot: usize,
    ) -> Result<()> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let (l, r, h, dh) = (c.n_layers, s.decode_batch, c.n_heads, c.d_head);
        let cap = dst.capacity;
        if src.capacity != cap || src.variant != dst.variant {
            bail!(
                "splice_slot: cache mismatch ({:?}/{} vs {:?}/{})",
                src.variant,
                src.capacity,
                dst.variant,
                cap
            );
        }
        if src_slot >= r || dst_slot >= r {
            bail!("splice_slot: slot {src_slot}->{dst_slot} out of range (R = {r})");
        }
        splice_f32(&mut dst.kv, &src.kv, l * 2, r, h * cap * dh, src_slot, dst_slot,
            &[l, 2, r, h, cap, dh])?;
        splice_f32(&mut dst.stats_cum, &src.stats_cum, l, r, h * cap, src_slot, dst_slot,
            &[l, r, h, cap])?;
        splice_f32(&mut dst.stats_win, &src.stats_win, l, r, h * cap, src_slot, dst_slot,
            &[l, r, h, cap])?;
        splice_i32(&mut dst.birth, &src.birth, l, r, h * cap, src_slot, dst_slot,
            &[l, r, h, cap])?;
        Ok(())
    }

    /// One decode step over the batch; returns log-probs [R, V] flattened
    /// and replaces the cache state in place. This is THE hot path: the
    /// cache literals flow straight back in, and only the small control
    /// vectors (lens/pos/token) are fresh allocations.
    pub fn decode(
        &self,
        params: &ParamsLit,
        cache: &mut CacheState,
        lens: &[i32],
        pos: &[i32],
        token: &[i32],
    ) -> Result<Vec<f32>> {
        let s = &self.manifest.shapes;
        let name = format!("decode_{}", cache.variant.name());
        let exe = self.exe(&name)?;
        let r = s.decode_batch;
        let lens_l = HostTensor::i32(lens.to_vec(), &[r]).to_literal()?;
        let pos_l = HostTensor::i32(pos.to_vec(), &[r]).to_literal()?;
        let tok_l = HostTensor::i32(token.to_vec(), &[r]).to_literal()?;
        let out = exe.run_literals(&[
            &params.0,
            &cache.kv,
            &cache.stats_cum,
            &cache.stats_win,
            &cache.birth,
            &lens_l,
            &pos_l,
            &tok_l,
        ])?;
        let mut it = out.into_iter();
        let logp = it
            .next()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("decode logp: {e:?}"))?;
        cache.kv = it.next().unwrap();
        cache.stats_cum = it.next().unwrap();
        cache.stats_win = it.next().unwrap();
        cache.birth = it.next().unwrap();
        Ok(logp)
    }

    /// Compress the sequences with `do_mask[b] = 1.0` down to the budget.
    pub fn compress(
        &self,
        method: Method,
        cache: &mut CacheState,
        do_mask: &[f32],
    ) -> Result<()> {
        if cache.variant != Variant::Sparse {
            bail!("compress called on a dense cache");
        }
        let s = &self.manifest.shapes;
        let name = format!("compress_{}", method.name());
        let exe = self.exe(&name)?;
        let do_l = HostTensor::f32(do_mask.to_vec(), &[s.decode_batch]).to_literal()?;
        let out = exe.run_literals(&[
            &cache.kv,
            &cache.stats_cum,
            &cache.stats_win,
            &cache.birth,
            &do_l,
        ])?;
        let mut it = out.into_iter();
        cache.kv = it.next().unwrap();
        cache.stats_cum = it.next().unwrap();
        cache.stats_win = it.next().unwrap();
        cache.birth = it.next().unwrap();
        Ok(())
    }

    /// Dense teacher-forcing scores: per-token log π(ids[t] | ids[<t]) and
    /// predictive entropy, both [Btr, T] flattened.
    pub fn score(
        &self,
        params: &[f32],
        ids: &[i32],
        lens: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let exe = self.exe("score")?;
        let out = exe.run(&[
            HostTensor::f32(params.to_vec(), &[c.n_params]),
            HostTensor::i32(ids.to_vec(), &[s.train_batch, c.max_seq]),
            HostTensor::i32(lens.to_vec(), &[s.train_batch]),
        ])?;
        let mut it = out.into_iter();
        let logp = it.next().unwrap().as_f32()?.to_vec();
        let ent = it.next().unwrap().as_f32()?.to_vec();
        Ok((logp, ent))
    }

    /// Inputs for one RL train step over [Btr, T].
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &self,
        state: &mut TrainState,
        ids: &[i32],
        loss_mask: &[f32],
        lens: &[i32],
        adv: &[f32],
        xi: &[f32],
        mrs: &[f32],
        logp_old: &[f32],
        hyp: Hyp,
    ) -> Result<TrainStats> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let exe = self.exe("train")?;
        let (b, t, n) = (s.train_batch, c.max_seq, c.n_params);
        let out = exe.run(&[
            HostTensor::f32(std::mem::take(&mut state.params), &[n]),
            HostTensor::f32(std::mem::take(&mut state.m), &[n]),
            HostTensor::f32(std::mem::take(&mut state.v), &[n]),
            HostTensor::scalar_i32(state.step),
            HostTensor::i32(ids.to_vec(), &[b, t]),
            HostTensor::f32(loss_mask.to_vec(), &[b, t]),
            HostTensor::i32(lens.to_vec(), &[b]),
            HostTensor::f32(adv.to_vec(), &[b]),
            HostTensor::f32(xi.to_vec(), &[b, t]),
            HostTensor::f32(mrs.to_vec(), &[b]),
            HostTensor::f32(logp_old.to_vec(), &[b, t]),
            hyp.tensor(),
        ])?;
        let mut it = out.into_iter();
        state.params = it.next().unwrap().as_f32()?.to_vec();
        state.m = it.next().unwrap().as_f32()?.to_vec();
        state.v = it.next().unwrap().as_f32()?.to_vec();
        state.step = it.next().unwrap().as_i32()?[0];
        Ok(TrainStats {
            loss: it.next().unwrap().scalar()?,
            grad_norm: it.next().unwrap().scalar()?,
            clip_frac: it.next().unwrap().scalar()?,
            entropy: it.next().unwrap().scalar()?,
            kl: it.next().unwrap().scalar()?,
        })
    }

    /// One supervised LM (pretraining) step; returns the CE loss.
    pub fn lm(
        &self,
        state: &mut TrainState,
        ids: &[i32],
        mask: &[f32],
        lens: &[i32],
        hyp: Hyp,
    ) -> Result<f64> {
        let s = &self.manifest.shapes;
        let c = &self.manifest.config;
        let exe = self.exe("lm")?;
        let (b, t, n) = (s.train_batch, c.max_seq, c.n_params);
        let out = exe.run(&[
            HostTensor::f32(std::mem::take(&mut state.params), &[n]),
            HostTensor::f32(std::mem::take(&mut state.m), &[n]),
            HostTensor::f32(std::mem::take(&mut state.v), &[n]),
            HostTensor::scalar_i32(state.step),
            HostTensor::i32(ids.to_vec(), &[b, t]),
            HostTensor::f32(mask.to_vec(), &[b, t]),
            HostTensor::i32(lens.to_vec(), &[b]),
            hyp.tensor(),
        ])?;
        let mut it = out.into_iter();
        state.params = it.next().unwrap().as_f32()?.to_vec();
        state.m = it.next().unwrap().as_f32()?.to_vec();
        state.v = it.next().unwrap().as_f32()?.to_vec();
        state.step = it.next().unwrap().as_i32()?[0];
        it.next().unwrap().scalar()
    }

    /// Per-entry mean latency report (perf instrumentation).
    pub fn latency_report(&self) -> Vec<(String, u64, f64)> {
        self.exes
            .lock()
            .map(|exes| {
                exes.iter()
                    .map(|(n, e)| (n.clone(), e.calls(), e.mean_latency_ns()))
                    .collect()
            })
            .unwrap_or_default()
    }
}

/// THE scratch prompt batch of the artifact-path slot prefills (fused
/// entry and prepare-for-splice alike): `prompt` in row `slot`, every
/// other row a minimal valid single-token row (`prompt[0]` filler — its
/// planes are discarded by the mask/splice, and batch-row independence
/// keeps its content out of the target row). One implementation so the
/// two call sites cannot drift.
fn scratch_prompt_batch(
    r: usize,
    p_len: usize,
    slot: usize,
    prompt: &[i32],
) -> (Vec<i32>, Vec<i32>) {
    let mut ids = vec![prompt[0]; r * p_len];
    let mut plens = vec![1i32; r];
    ids[slot * p_len..slot * p_len + prompt.len()].copy_from_slice(prompt);
    plens[slot] = prompt.len() as i32;
    (ids, plens)
}

/// One decode slot's cache planes, host-side and compact ([outer, plane]
/// row-major per tensor — the R axis removed). The unit a slot's state
/// travels in between backends: `ModelEngine::extract_slot` produces it,
/// `implant_slot` lands it, and the async prefill executor's prepared
/// payload carries exactly one of these instead of a full R-slot cache.
#[derive(Clone)]
pub struct SlotPlanes {
    kv: Vec<f32>,
    stats_cum: Vec<f32>,
    stats_win: Vec<f32>,
    birth: Vec<i32>,
    capacity: usize,
    variant: Variant,
}

/// Manifest entry name of the fused slot-masked prefill for `variant`
/// (`prefill_slot_dense` / `prefill_slot_sparse`). `prefill_slot`
/// dispatches on `Manifest::has_entry` of this name: artifact sets built
/// before the entry existed simply lack it and fall back to the
/// scratch-batch host splice.
pub fn fused_prefill_entry(variant: Variant) -> String {
    format!("prefill_slot_{}", variant.name())
}

/// Manifest entry name of the fused partial-range prefill for `variant`
/// (`prefill_chunk_dense` / `prefill_chunk_sparse`). `prefill_chunk`
/// dispatches on `Manifest::has_entry` of this name: artifact sets built
/// before the entry existed fall back to deferring intermediate chunks
/// and running the monolithic slot prefill on the final one.
pub fn chunk_prefill_entry(variant: Variant) -> String {
    format!("prefill_chunk_{}", variant.name())
}

/// Copy slot `src_slot`'s plane from `src` into slot `dst_slot` of `dst`
/// for a tensor whose row-major layout is [outer.., R, plane..]: `outer`
/// leading blocks, each holding R slot planes of `plane` elements (the
/// slot axis of every cache tensor). Host round-trip; see `splice_slot`.
/// One macro-generated body per element type so the bounds/copy logic
/// cannot drift between the f32 (kv/stats) and i32 (birth) variants.
macro_rules! splice_plane {
    ($name:ident, $ty:ty) => {
        #[allow(clippy::too_many_arguments)]
        fn $name(
            dst: &mut xla::Literal,
            src: &xla::Literal,
            outer: usize,
            r: usize,
            plane: usize,
            src_slot: usize,
            dst_slot: usize,
            dims: &[usize],
        ) -> Result<()> {
            let mut d = dst
                .to_vec::<$ty>()
                .map_err(|e| anyhow::anyhow!("splice dst: {e:?}"))?;
            let s = src
                .to_vec::<$ty>()
                .map_err(|e| anyhow::anyhow!("splice src: {e:?}"))?;
            if d.len() != s.len() || d.len() != outer * r * plane {
                bail!(
                    "splice: layout mismatch (dst {}, src {}, expect {})",
                    d.len(),
                    s.len(),
                    outer * r * plane
                );
            }
            for o in 0..outer {
                let sbase = (o * r + src_slot) * plane;
                let dbase = (o * r + dst_slot) * plane;
                d[dbase..dbase + plane].copy_from_slice(&s[sbase..sbase + plane]);
            }
            let dims_i64: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
            *dst = xla::Literal::vec1(&d).reshape(&dims_i64)?;
            Ok(())
        }
    };
}

splice_plane!(splice_f32, f32);
splice_plane!(splice_i32, i32);

/// Extract/implant one slot's plane as a compact [outer, plane] buffer
/// for a tensor laid out [outer.., R, plane..] — the splice split into
/// its read and write halves, so a single slot's state can travel
/// without the other R-1 slots (see `SlotPlanes`). Same macro discipline
/// as `splice_plane`: one body per element type.
macro_rules! slot_plane_ops {
    ($ext:ident, $imp:ident, $ty:ty) => {
        fn $ext(
            src: &xla::Literal,
            outer: usize,
            r: usize,
            plane: usize,
            slot: usize,
        ) -> Result<Vec<$ty>> {
            let s = src
                .to_vec::<$ty>()
                .map_err(|e| anyhow::anyhow!("extract src: {e:?}"))?;
            if s.len() != outer * r * plane {
                bail!("extract: layout mismatch ({} != {})", s.len(), outer * r * plane);
            }
            let mut out = Vec::with_capacity(outer * plane);
            for o in 0..outer {
                let base = (o * r + slot) * plane;
                out.extend_from_slice(&s[base..base + plane]);
            }
            Ok(out)
        }

        fn $imp(
            dst: &mut xla::Literal,
            compact: &[$ty],
            outer: usize,
            r: usize,
            plane: usize,
            slot: usize,
            dims: &[usize],
        ) -> Result<()> {
            let mut d = dst
                .to_vec::<$ty>()
                .map_err(|e| anyhow::anyhow!("implant dst: {e:?}"))?;
            if d.len() != outer * r * plane || compact.len() != outer * plane {
                bail!(
                    "implant: layout mismatch (dst {}, compact {}, expect {}/{})",
                    d.len(),
                    compact.len(),
                    outer * r * plane,
                    outer * plane
                );
            }
            for o in 0..outer {
                let base = (o * r + slot) * plane;
                d[base..base + plane].copy_from_slice(&compact[o * plane..(o + 1) * plane]);
            }
            let dims_i64: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
            *dst = xla::Literal::vec1(&d).reshape(&dims_i64)?;
            Ok(())
        }
    };
}

slot_plane_ops!(extract_f32, implant_f32, f32);
slot_plane_ops!(extract_i32, implant_i32, i32);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Manifest, ModelDims, RolloutDims};
    use std::path::PathBuf;

    fn bare_manifest(entries: &[&str]) -> Manifest {
        // Only `entries` matters for the fused-prefill dispatch; the rest
        // is a minimal well-formed shell (tests never execute anything).
        let mut map = BTreeMap::new();
        for name in entries {
            map.insert(
                name.to_string(),
                crate::runtime::manifest::EntrySpec {
                    name: name.to_string(),
                    file: PathBuf::from(format!("{name}.hlo.txt")),
                    inputs: vec![],
                    outputs: vec![],
                },
            );
        }
        Manifest {
            dir: PathBuf::from("test-artifacts"),
            config: ModelDims {
                name: "unit".into(),
                vocab: 32,
                d_model: 8,
                n_layers: 1,
                n_heads: 1,
                d_ff: 16,
                d_head: 8,
                max_seq: 32,
                prompt_len: 8,
                n_params: 0,
            },
            shapes: RolloutDims {
                decode_batch: 2,
                train_batch: 2,
                budget: 8,
                buffer: 4,
                alpha: 2,
                lam: 0.1,
                sinks: 2,
                sparse_capacity: 12,
                dense_capacity: 32,
            },
            params: vec![],
            entries: map,
        }
    }

    #[test]
    fn fused_prefill_dispatch_is_manifest_gated() {
        // the dispatch rule `prefill_slot` implements: fused entry when
        // the manifest carries it, scratch-batch splice fallback when not
        assert_eq!(fused_prefill_entry(Variant::Dense), "prefill_slot_dense");
        assert_eq!(fused_prefill_entry(Variant::Sparse), "prefill_slot_sparse");
        let old = bare_manifest(&["prefill_dense", "decode_dense"]);
        assert!(!old.has_entry(&fused_prefill_entry(Variant::Dense)));
        assert!(!old.has_entry(&fused_prefill_entry(Variant::Sparse)));
        let new = bare_manifest(&[
            "prefill_dense",
            "decode_dense",
            "prefill_slot_dense",
            "prefill_slot_sparse",
        ]);
        assert!(new.has_entry(&fused_prefill_entry(Variant::Dense)));
        assert!(new.has_entry(&fused_prefill_entry(Variant::Sparse)));
    }

    #[test]
    fn chunk_prefill_dispatch_is_manifest_gated() {
        // the dispatch rule `prefill_chunk` implements: fused partial-
        // range entry when the manifest carries it, defer-then-monolithic
        // fallback when not
        assert_eq!(chunk_prefill_entry(Variant::Dense), "prefill_chunk_dense");
        assert_eq!(chunk_prefill_entry(Variant::Sparse), "prefill_chunk_sparse");
        let old = bare_manifest(&["prefill_dense", "prefill_slot_dense"]);
        assert!(!old.has_entry(&chunk_prefill_entry(Variant::Dense)));
        assert!(!old.has_entry(&chunk_prefill_entry(Variant::Sparse)));
        let new = bare_manifest(&[
            "prefill_dense",
            "prefill_slot_dense",
            "prefill_chunk_dense",
            "prefill_chunk_sparse",
        ]);
        assert!(new.has_entry(&chunk_prefill_entry(Variant::Dense)));
        assert!(new.has_entry(&chunk_prefill_entry(Variant::Sparse)));
    }

    #[test]
    fn splice_plane_copies_across_slots() {
        // layout [outer=2, R=3, plane=2]: slot planes must move between
        // slot positions without touching any other slot
        let dims = [2usize, 3, 2];
        let src_data: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let dst_data = vec![-1.0f32; 12];
        let dims_i64: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        let src = xla::Literal::vec1(&src_data).reshape(&dims_i64).unwrap();
        let mut dst = xla::Literal::vec1(&dst_data).reshape(&dims_i64).unwrap();
        splice_f32(&mut dst, &src, 2, 3, 2, 0, 2, &dims).unwrap();
        let out = dst.to_vec::<f32>().unwrap();
        // outer 0: src slot 0 = [0,1] lands in dst slot 2; outer 1: src
        // slot 0 = [6,7] lands in dst slot 2; everything else untouched
        assert_eq!(
            out,
            vec![-1.0, -1.0, -1.0, -1.0, 0.0, 1.0, -1.0, -1.0, -1.0, -1.0, 6.0, 7.0]
        );
        // same-slot splice reproduces the original behavior
        let mut dst2 = xla::Literal::vec1(&dst_data).reshape(&dims_i64).unwrap();
        splice_f32(&mut dst2, &src, 2, 3, 2, 1, 1, &dims).unwrap();
        let out2 = dst2.to_vec::<f32>().unwrap();
        assert_eq!(
            out2,
            vec![-1.0, -1.0, 2.0, 3.0, -1.0, -1.0, -1.0, -1.0, 8.0, 9.0, -1.0, -1.0]
        );
    }

    #[test]
    fn extract_then_implant_equals_splice() {
        // the async payload path (extract a slot's compact planes, implant
        // them elsewhere) must land exactly what a direct cross-slot
        // splice would — the contract apply_prefill rests on
        let dims = [2usize, 3, 2];
        let dims_i64: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        let src_data: Vec<f32> = (0..12).map(|x| x as f32 + 0.5).collect();
        let dst_data = vec![-7.0f32; 12];
        let src = xla::Literal::vec1(&src_data).reshape(&dims_i64).unwrap();

        // compact planes of slot 1: [outer, plane] = [[2.5,3.5],[8.5,9.5]]
        let compact = extract_f32(&src, 2, 3, 2, 1).unwrap();
        assert_eq!(compact, vec![2.5, 3.5, 8.5, 9.5]);

        let mut via_implant = xla::Literal::vec1(&dst_data).reshape(&dims_i64).unwrap();
        implant_f32(&mut via_implant, &compact, 2, 3, 2, 0, &dims).unwrap();
        let mut via_splice = xla::Literal::vec1(&dst_data).reshape(&dims_i64).unwrap();
        splice_f32(&mut via_splice, &src, 2, 3, 2, 1, 0, &dims).unwrap();
        assert_eq!(
            via_implant.to_vec::<f32>().unwrap(),
            via_splice.to_vec::<f32>().unwrap()
        );
        // shape mismatches are loud, not silent
        assert!(implant_f32(&mut via_implant, &compact[..2], 2, 3, 2, 0, &dims).is_err());
        assert!(extract_f32(&src, 2, 4, 2, 1).is_err());
    }
}
