//! Loading and executing AOT artifacts on the PJRT client.
//!
//! `Executable` wraps one compiled entry point: it validates inputs against
//! the manifest signature, executes on the PJRT CPU client, and unpacks the
//! (return_tuple=True) tuple output back into `HostTensor`s. Compilation
//! happens once at load; execution is the request-path operation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::EntrySpec;
use super::tensor::HostTensor;

/// A compiled AOT entry point bound to its manifest signature.
///
/// Thread-safety: execution statistics are relaxed atomics so an
/// `Executable` can be shared (`Arc`) across the pipelined engine's worker
/// threads; the counters need no cross-counter consistency, only eventual
/// totals for the latency report.
pub struct Executable {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative execution statistics (relaxed atomics).
    calls: AtomicU64,
    total_ns: AtomicU64,
}

impl Executable {
    /// Load HLO text, compile on the client (one-time cost).
    pub fn load(client: &xla::PjRtClient, spec: &EntrySpec) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .with_context(|| format!("non-utf8 path {:?}", spec.file))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", spec.name))?;
        let dt = t0.elapsed();
        if dt.as_millis() > 500 {
            eprintln!("  compiled {} in {:.1}s", spec.name, dt.as_secs_f64());
        }
        Ok(Executable {
            spec: spec.clone(),
            exe,
            calls: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
        })
    }

    /// Executions so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Execute with host tensors; returns outputs in manifest order.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest says {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(self.spec.inputs.iter()) {
            t.check_spec(s)
                .with_context(|| format!("entry {} input {}", self.spec.name, s.name))?;
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        let parts = self.execute_via_buffers(&refs)?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(self.spec.outputs.iter())
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }

    /// Mean execution latency so far (ns), for the perf report.
    pub fn mean_latency_ns(&self) -> f64 {
        let c = self.calls.load(Ordering::Relaxed);
        if c == 0 {
            0.0
        } else {
            self.total_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Hot-path execute: literals in, literals out, no HostTensor
    /// conversion. State tensors (KV cache etc.) stay as literals between
    /// steps, saving two full copies per tensor per call relative to
    /// `run` (see EXPERIMENTS.md §Perf). Only the argument *count* is
    /// checked; shapes are trusted because state literals originate from
    /// this executable family's own outputs.
    pub fn run_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest says {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let parts = self.execute_via_buffers(inputs)?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Leak-free execution core.
    ///
    /// MEMORY-SAFETY NOTE: the crate's literal-based `execute` C++ shim
    /// creates a device buffer per input and `release()`s it without ever
    /// freeing (vendor/xla/xla_rs/xla_rs.cc) — every call leaks all input
    /// bytes, which OOM-killed multi-thousand-call RL runs (§Perf log #4).
    /// We instead create the input buffers ourselves (`PjRtBuffer` has a
    /// proper Drop) and go through `execute_b`, which borrows.
    fn execute_via_buffers(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let client = self.exe.client();
        let t0 = Instant::now();
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|l| {
                client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow::anyhow!("uploading {} input: {e:?}", self.spec.name))
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.spec.name))?;
        drop(bufs); // inputs freed here — the whole point
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {} output: {e:?}", self.spec.name))?;
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.total_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out_lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {} output: {e:?}", self.spec.name))
    }
}
