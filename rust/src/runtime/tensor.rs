//! Host-side tensors and conversion to/from XLA literals.
//!
//! The coordinator works entirely in `HostTensor`s (flat storage + dims);
//! conversion to `xla::Literal` happens at the executable boundary. On the
//! CPU PJRT backend these conversions are memcpys, not device transfers.

use anyhow::{bail, Result};

use super::manifest::{DType, TensorSpec};

/// A host tensor: flat row-major storage plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        HostTensor::F32 { data, dims: dims.to_vec() }
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        HostTensor::I32 { data, dims: dims.to_vec() }
    }

    pub fn zeros_f32(dims: &[usize]) -> Self {
        HostTensor::F32 { data: vec![0.0; dims.iter().product()], dims: dims.to_vec() }
    }

    pub fn zeros_i32(dims: &[usize]) -> Self {
        HostTensor::I32 { data: vec![0; dims.iter().product()], dims: dims.to_vec() }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { data: vec![v], dims: vec![] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { data: vec![v], dims: vec![] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32 { dims, .. } | HostTensor::I32 { dims, .. } => dims,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn elems(&self) -> usize {
        self.dims().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32_mut(&mut self) -> Result<&mut [i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Scalar extraction (any rank-0 or single-element tensor).
    pub fn scalar(&self) -> Result<f64> {
        match self {
            HostTensor::F32 { data, .. } if data.len() == 1 => Ok(data[0] as f64),
            HostTensor::I32 { data, .. } if data.len() == 1 => Ok(data[0] as f64),
            _ => bail!("tensor is not a scalar (elems = {})", self.elems()),
        }
    }

    /// Check against a manifest tensor spec.
    pub fn check_spec(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!("tensor {}: dtype {:?} != manifest {:?}", spec.name, self.dtype(), spec.dtype);
        }
        if self.dims() != spec.dims.as_slice() {
            bail!(
                "tensor {}: dims {:?} != manifest {:?}",
                spec.name,
                self.dims(),
                spec.dims
            );
        }
        Ok(())
    }

    /// Convert to an XLA literal (host copy).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, dims } => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(&dims_i64)?
                }
            }
            HostTensor::I32 { data, dims } => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(&dims_i64)?
                }
            }
        };
        Ok(lit)
    }

    /// Convert from an XLA literal using the manifest spec for dims/dtype.
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        let t = match spec.dtype {
            DType::F32 => HostTensor::F32 { data: lit.to_vec::<f32>()?, dims: spec.dims.clone() },
            DType::I32 => HostTensor::I32 { data: lit.to_vec::<i32>()?, dims: spec.dims.clone() },
        };
        if t.elems() != spec.elems() {
            bail!(
                "output {}: literal has {} elems, manifest says {}",
                spec.name,
                t.elems(),
                spec.elems()
            );
        }
        Ok(t)
    }

    /// Row-major index helper.
    pub fn index(&self, idx: &[usize]) -> usize {
        let dims = self.dims();
        debug_assert_eq!(idx.len(), dims.len());
        let mut flat = 0usize;
        for (i, &d) in idx.iter().zip(dims.iter()) {
            debug_assert!(*i < d || d == 0, "index {i} out of dim {d}");
            let _ = d;
            flat = flat * d + i;
        }
        flat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_row_major() {
        let t = HostTensor::zeros_f32(&[2, 3, 4]);
        assert_eq!(t.index(&[0, 0, 0]), 0);
        assert_eq!(t.index(&[0, 0, 3]), 3);
        assert_eq!(t.index(&[0, 1, 0]), 4);
        assert_eq!(t.index(&[1, 2, 3]), 23);
    }

    #[test]
    fn spec_check() {
        let spec = TensorSpec { name: "x".into(), dtype: DType::F32, dims: vec![2, 2] };
        assert!(HostTensor::zeros_f32(&[2, 2]).check_spec(&spec).is_ok());
        assert!(HostTensor::zeros_f32(&[2, 3]).check_spec(&spec).is_err());
        assert!(HostTensor::zeros_i32(&[2, 2]).check_spec(&spec).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let lit = t.to_literal().unwrap();
        let spec = TensorSpec { name: "x".into(), dtype: DType::F32, dims: vec![2, 2] };
        let t2 = HostTensor::from_literal(&lit, &spec).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn scalar_extraction_errors_on_vectors() {
        assert!(HostTensor::zeros_f32(&[3]).scalar().is_err());
        assert_eq!(HostTensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
        assert_eq!(HostTensor::scalar_i32(-3).scalar().unwrap(), -3.0);
    }

    #[test]
    fn literal_scalar() {
        let t = HostTensor::scalar_i32(7);
        let lit = t.to_literal().unwrap();
        let spec = TensorSpec { name: "s".into(), dtype: DType::I32, dims: vec![] };
        let t2 = HostTensor::from_literal(&lit, &spec).unwrap();
        assert_eq!(t2.as_i32().unwrap(), &[7]);
    }
}
