//! Runtime layer: PJRT client + AOT artifact loading and execution.
//!
//! Python is build-time only; this module is how the Rust coordinator runs
//! the compiled model. See /opt/xla-example/README.md for the HLO-text
//! interchange rationale (xla_extension 0.5.1 rejects jax>=0.5 serialized
//! protos; text round-trips).

pub mod engine;
pub mod executable;
pub mod manifest;
pub mod params;
pub mod tensor;

pub use engine::{
    chunk_prefill_entry, fused_prefill_entry, CacheState, Hyp, Method, ModelEngine, ParamsLit,
    SlotPlanes, TrainState, TrainStats, Variant,
};
pub use manifest::Manifest;
pub use tensor::HostTensor;
