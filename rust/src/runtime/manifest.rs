//! Typed view of `artifacts/<model>/manifest.json` (written by aot.py).
//!
//! The manifest is the single source of truth for every tensor shape the
//! Rust side touches: entry-point signatures, the flat parameter layout,
//! and the model/rollout hyper-parameters the artifacts were specialized
//! for. Nothing on the Rust side hard-codes a shape.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element type of a tensor in the artifact interface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?} in manifest"),
        }
    }
}

/// One input/output tensor of an entry point.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT entry point (an HLO text file + its signature).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Flat-parameter layout entry (mirrors model.ParamLayout).
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Model dimensions the artifacts were built for.
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub d_head: usize,
    pub max_seq: usize,
    pub prompt_len: usize,
    pub n_params: usize,
}

/// Rollout/compression shape constants baked into the artifacts.
#[derive(Debug, Clone)]
pub struct RolloutDims {
    pub decode_batch: usize,
    pub train_batch: usize,
    pub budget: usize,
    pub buffer: usize,
    pub alpha: usize,
    pub lam: f64,
    pub sinks: usize,
    pub sparse_capacity: usize,
    pub dense_capacity: usize,
}

/// Fully parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelDims,
    pub shapes: RolloutDims,
    pub params: Vec<ParamEntry>,
    pub entries: BTreeMap<String, EntrySpec>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    let arr = j.as_arr().context("expected array of tensor specs")?;
    arr.iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.get("name").as_str().context("tensor name")?.to_string(),
                dtype: DType::parse(t.get("dtype").as_str().context("tensor dtype")?)?,
                dims: t
                    .get("dims")
                    .as_arr()
                    .context("tensor dims")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let c = j.get("config");
        let u = |k: &str| -> Result<usize> {
            c.get(k).as_usize().with_context(|| format!("config.{k}"))
        };
        let config = ModelDims {
            name: c.get("name").as_str().context("config.name")?.to_string(),
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            d_ff: u("d_ff")?,
            d_head: u("d_head")?,
            max_seq: u("max_seq")?,
            prompt_len: u("prompt_len")?,
            n_params: u("n_params")?,
        };

        let s = j.get("shapes");
        let su = |k: &str| -> Result<usize> {
            s.get(k).as_usize().with_context(|| format!("shapes.{k}"))
        };
        let shapes = RolloutDims {
            decode_batch: su("decode_batch")?,
            train_batch: su("train_batch")?,
            budget: su("budget")?,
            buffer: su("buffer")?,
            alpha: su("alpha")?,
            lam: s.get("lam").as_f64().context("shapes.lam")?,
            sinks: su("sinks")?,
            sparse_capacity: su("sparse_capacity")?,
            dense_capacity: su("dense_capacity")?,
        };

        let params = j
            .get("params")
            .as_arr()
            .context("params")?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p.get("name").as_str().context("param name")?.to_string(),
                    shape: p
                        .get("shape")
                        .as_arr()
                        .context("param shape")?
                        .iter()
                        .map(|d| d.as_usize().context("param dim"))
                        .collect::<Result<_>>()?,
                    offset: p.get("offset").as_usize().context("param offset")?,
                    size: p.get("size").as_usize().context("param size")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut entries = BTreeMap::new();
        for (name, e) in j.get("entries").as_obj().context("entries")? {
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: dir.join(e.get("file").as_str().context("entry file")?),
                    inputs: tensor_specs(e.get("inputs"))?,
                    outputs: tensor_specs(e.get("outputs"))?,
                },
            );
        }

        let m = Manifest { dir: dir.to_path_buf(), config, shapes, params, entries };
        m.validate()?;
        Ok(m)
    }

    /// Internal consistency checks (cheap; run at load).
    fn validate(&self) -> Result<()> {
        // Param layout must tile [0, n_params) exactly.
        let mut off = 0usize;
        for p in &self.params {
            if p.offset != off {
                bail!("param {} offset {} != expected {}", p.name, p.offset, off);
            }
            let sz: usize = p.shape.iter().product();
            if sz != p.size {
                bail!("param {} size mismatch", p.name);
            }
            off += p.size;
        }
        if off != self.config.n_params {
            bail!("param layout covers {} of {} params", off, self.config.n_params);
        }
        if self.shapes.sparse_capacity != self.shapes.budget + self.shapes.buffer {
            bail!("sparse_capacity != budget + buffer");
        }
        for e in self.entries.values() {
            if !e.file.exists() {
                bail!("artifact file missing: {}", e.file.display());
            }
        }
        Ok(())
    }

    /// Whether the artifact set ships entry point `name`. Optional entries
    /// (e.g. the fused slot-masked prefill, `prefill_slot_<variant>`) are
    /// feature-gated on this: artifacts built before an entry existed
    /// simply lack it and the engine falls back to the portable path.
    pub fn has_entry(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("entry point {name:?} not in manifest (have: {:?})",
                self.entries.keys().collect::<Vec<_>>()))
    }

    /// KV bytes per sequence at a given cache capacity (f32 K+V).
    pub fn kv_bytes_per_seq(&self, capacity: usize) -> usize {
        self.config.n_layers * 2 * self.config.n_heads * capacity * self.config.d_head * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("i32").unwrap(), DType::I32);
        assert!(DType::parse("f64").is_err());
    }
}
