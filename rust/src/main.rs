//! sparse-rl launcher: the L3 leader entrypoint.
//!
//! Subcommands:
//!   pretrain   supervised base-model pretraining (worked examples)
//!   train      RL training (dense | naive:<m> | sparse-rl:<m>)
//!   eval       benchmark-suite evaluation of a checkpoint
//!   rollout    print sample generations (debugging / demos)
//!   serve      streaming serving front-end on a deterministic arrival
//!              trace (SLO admission, shedding, latency histograms)
//!   table3     print the benchmark-statistics table (paper Table 3)
//!   latency    per-artifact execution latency report
//!
//! Everything is driven by `--model <preset>` (artifact lookup) plus the
//! config keys in `config::ExperimentConfig` (`--steps`, `--mode`, `--lr`,
//! ... or `--config file.conf`).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::coordinator::engine::RolloutEngine;
use sparse_rl::data::{benchmarks, tokenizer};
use sparse_rl::experiments;
use sparse_rl::runtime::{params, ModelEngine, TrainState};
use sparse_rl::util::cli::CliArgs;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: sparse-rl <pretrain|train|eval|rollout|serve|table3|latency> [options]
  common:   --model <nano|tiny|small|base|e2e>   --artifacts <dir>
  pretrain: --steps N --seed S --out ckpt.srl
  train:    --mode <dense|naive:M|sparse-rl:M> --steps N
            --init-checkpoint ckpt --out-dir runs/x  [config keys...]
  eval:     --checkpoint ckpt --mode <...> [--bench name] [--limit N]
            [--engine static|continuous|pipelined] [--rollout-workers N]
            [--steal on|off] [--admission-order fifo|shortest-first]
            [--prefill sync|async] [--prefix-sharing off|group]
            [--replicas N] [--replica-steal on|off]
            [--admission worst-case|paged] [--kv-admit-headroom-pages N]
            [--kv-page-tokens N] [--global-kv-tokens N]
            [--fault-retries N] [--fault-policy abort|quarantine]
            [--prefill-chunk-tokens N]
            (unrecognized --flags are an error listing the valid set)
  rollout:  --checkpoint ckpt --mode <...> [--n 4] [--temperature T]
  serve:    hermetic mock-backend serving demo (no artifacts needed)
            [--requests N] [--interarrival TICKS] [--slots N] [--seed S]
            [--serve-admission slo|fifo] [--serve-queue-depth N]
            [--serve-slo-ticks N] [--mode <...>] [--engine <...>]
            [--rollout-workers N] [--prefill sync|async] [...]"
    );
    std::process::exit(2);
}

fn load_engine(args: &CliArgs) -> Result<ModelEngine> {
    let dir = match args.opt("artifacts") {
        Some(d) => PathBuf::from(d),
        None => {
            let model = args.get("model", "tiny".to_string());
            experiments::find_artifacts(&model)?
        }
    };
    eprintln!("artifacts: {}", dir.display());
    ModelEngine::load(&dir)
}

fn load_state(engine: &ModelEngine, args: &CliArgs) -> Result<TrainState> {
    match args.opt("checkpoint").or_else(|| args.opt("init-checkpoint")) {
        Some(p) => {
            let (model, state) = params::load(&PathBuf::from(p), engine.manifest.config.n_params)?;
            anyhow::ensure!(
                model == engine.manifest.config.name,
                "checkpoint is for {model}, artifacts are {}",
                engine.manifest.config.name
            );
            Ok(state)
        }
        None => Ok(TrainState::new(engine.init_params(args.get("seed", 0u64) as i32)?)),
    }
}

fn run() -> Result<()> {
    let args = CliArgs::from_env();
    let cmd = match args.positional.first() {
        Some(c) => c.clone(),
        None => usage(),
    };
    match cmd.as_str() {
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "rollout" => cmd_rollout(&args),
        "serve" => cmd_serve(&args),
        "table3" => cmd_table3(),
        "latency" => cmd_latency(&args),
        other => {
            eprintln!("unknown subcommand {other:?}");
            usage()
        }
    }
}

fn cmd_pretrain(args: &CliArgs) -> Result<()> {
    let engine = load_engine(args)?;
    let steps = args.get(
        "steps",
        experiments::default_pretrain_steps(&engine.manifest.config.name),
    );
    let seed = args.get("seed", 0u64);
    let (state, losses) = experiments::pretrain_base(&engine, steps, seed, 25)?;
    let default_out = format!(
        "runs/base/{}-s{}-seed{}.srl",
        engine.manifest.config.name, steps, seed
    );
    let out = PathBuf::from(args.get("out", default_out));
    params::save(&out, &engine.manifest.config.name, &state, false)?;
    println!(
        "pretrained {} for {} steps (final ce-loss {:.4}) -> {}",
        engine.manifest.config.name,
        steps,
        losses.last().copied().unwrap_or(f64::NAN),
        out.display()
    );
    Ok(())
}

fn cmd_train(args: &CliArgs) -> Result<()> {
    let engine = load_engine(args)?;
    let mut cfg = ExperimentConfig::new(&engine.manifest.dir);
    cfg.apply_cli(args)?;
    let state = load_state(&engine, args)?;
    println!(
        "RL training: model={} mode={} steps={} prompts/step={} G={}",
        engine.manifest.config.name,
        cfg.mode.label(),
        cfg.train.steps,
        cfg.train.prompts_per_step,
        cfg.train.group_size
    );
    let trainer = experiments::run_rl(&engine, cfg, state, args.get("print-every", 1usize))?;
    let tag = trainer.cfg.mode.label().replace(':', "-");
    let (csv, ckpt) = experiments::save_run(&trainer, &tag)?;
    println!("metrics -> {}\ncheckpoint -> {}", csv.display(), ckpt.display());
    Ok(())
}

/// Options the eval subcommand accepts beyond `ExperimentConfig`'s keys.
const EVAL_EXTRA_KEYS: &[&str] = &["model", "checkpoint", "limit", "bench", "config"];

/// Hard-reject unrecognized `--flag`s. `apply_cli` deliberately ignores
/// keys it doesn't know (every subcommand carries extras like `--bench`),
/// which silently turned typos into misconfigured runs — `--replica 4`
/// evaluated on one replica. Each subcommand whitelists its extras and
/// anything else errors, listing the valid flags.
fn reject_unknown_options(args: &CliArgs, extras: &[&str]) -> Result<()> {
    let unknown: Vec<String> = args
        .options
        .keys()
        .chain(args.flags.iter())
        .filter(|k| {
            !ExperimentConfig::is_known_key(k) && !extras.contains(&k.as_str())
        })
        .map(|k| format!("--{k}"))
        .collect();
    if unknown.is_empty() {
        return Ok(());
    }
    let mut valid: Vec<&str> = ExperimentConfig::KNOWN_KEYS
        .iter()
        .copied()
        .chain(extras.iter().copied())
        .collect();
    valid.sort_unstable();
    bail!(
        "unknown option{} {} — valid flags: {}",
        if unknown.len() == 1 { "" } else { "s" },
        unknown.join(", "),
        valid
            .iter()
            .map(|k| format!("--{k}"))
            .collect::<Vec<_>>()
            .join(" ")
    )
}

fn cmd_eval(args: &CliArgs) -> Result<()> {
    reject_unknown_options(args, EVAL_EXTRA_KEYS)?;
    let engine = load_engine(args)?;
    let state = load_state(&engine, args)?;
    let mode = RolloutMode::parse(&args.get("mode", "dense".to_string()))?;
    let limit = args.get("limit", 50usize);
    let seed = args.get("seed", 0u64);
    // the trainer's engine/memory knobs apply to evaluation too
    // (--engine continuous, --admission paged, --kv-page-tokens N, ...)
    let mut cfg = ExperimentConfig::new(&engine.manifest.dir);
    cfg.apply_cli(args)?;
    // apply_cli tolerates unknown/bad keys (subcommands have extras); the
    // knobs this subcommand advertises must fail loudly on a bad value
    for key in [
        "engine",
        "rollout-workers",
        "steal",
        "admission-order",
        "prefill",
        "prefill-chunk-tokens",
        "prefix-sharing",
        "replicas",
        "replica-steal",
        "admission",
        "kv-admit-headroom-pages",
        "kv-page-tokens",
        "global-kv-tokens",
        "fault-retries",
        "fault-policy",
    ] {
        if let Some(v) = args.opt(key) {
            cfg.apply(key, v).with_context(|| format!("--{key}"))?;
        }
    }
    let opts = sparse_rl::coordinator::EvalOptions::from_config(&cfg);
    match args.opt("bench") {
        Some(name) => {
            let suite = benchmarks::suite();
            let b = suite
                .iter()
                .find(|b| b.name == name)
                .with_context(|| format!("unknown benchmark {name:?}"))?;
            let r = sparse_rl::coordinator::evaluate(
                &engine,
                &state.params,
                mode,
                b,
                limit,
                seed,
                &opts,
            )?;
            println!(
                "{}: acc {:.3} over {} items ({} samples), mean len {:.1}, toks saved {:.2}",
                r.benchmark, r.accuracy, r.items, r.samples, r.mean_response_len, r.toks_saving
            );
        }
        None => {
            let (_results, avg) =
                experiments::eval_checkpoint(&engine, &state.params, mode, limit, seed, &opts)?;
            println!("suite average: {avg:.3} (mode {}, limit {limit})", mode.label());
        }
    }
    Ok(())
}

fn cmd_rollout(args: &CliArgs) -> Result<()> {
    let engine = load_engine(args)?;
    let state = load_state(&engine, args)?;
    let mode = RolloutMode::parse(&args.get("mode", "dense".to_string()))?;
    let mut cfg = ExperimentConfig::new(&engine.manifest.dir);
    cfg.apply_cli(args)?;
    let n = args.get("n", 4usize).min(engine.manifest.shapes.decode_batch);
    let seed = args.get("seed", 0u64);
    let tasks = benchmarks::training_split(n, engine.manifest.config.prompt_len, seed);
    let ro = RolloutEngine::new(&engine, mode, cfg.sampling);
    let chunk: Vec<(usize, &sparse_rl::data::Task)> =
        tasks.iter().enumerate().map(|(i, t)| (i, t)).collect();
    let seqs = ro.rollout_chunk(&state.params, &chunk, seed)?;
    for (seq, task) in seqs.iter().zip(tasks.iter()) {
        println!(
            "prompt: {}\nanswer: {}  reward: {}  compressions: {}  toks-saved: {:.2}",
            task.prompt_text,
            task.answer,
            task.reward(&seq.response_ids),
            seq.accounting.compressions,
            seq.accounting.toks_saving()
        );
        println!("response: {}\n", tokenizer::decode(&seq.response_ids));
    }
    Ok(())
}

/// Options the serve subcommand accepts beyond `ExperimentConfig`'s keys.
const SERVE_EXTRA_KEYS: &[&str] = &["requests", "interarrival", "slots", "config"];

/// Drive the streaming serving front-end over a deterministic open-loop
/// arrival trace on the mock backend — hermetic (no artifacts), with the
/// representative cost model providing the virtual clock, so the printed
/// TTFT / inter-token / e2e latencies and shed counts are reproducible
/// to the tick for a given flag set.
fn cmd_serve(args: &CliArgs) -> Result<()> {
    use sparse_rl::coordinator::{
        synthetic_trace, CostModel, KvMemoryManager, MockModelBackend, RolloutPolicy, Scheduler,
        ServeOutcome, ServeServer, ShedReason,
    };
    use sparse_rl::config::EngineKind;

    reject_unknown_options(args, SERVE_EXTRA_KEYS)?;
    let mut cfg = ExperimentConfig::new(std::path::Path::new("runs/serve"));
    cfg.apply_cli(args)?;
    // fail loudly on bad values for the knobs this subcommand advertises
    // (apply_cli tolerates extras, same contract as cmd_eval)
    for key in [
        "mode",
        "engine",
        "rollout-workers",
        "steal",
        "admission-order",
        "prefill",
        "prefill-chunk-tokens",
        "prefix-sharing",
        "admission",
        "kv-admit-headroom-pages",
        "kv-page-tokens",
        "global-kv-tokens",
        "serve-admission",
        "serve-queue-depth",
        "serve-slo-ticks",
    ] {
        if let Some(v) = args.opt(key) {
            cfg.apply(key, v).with_context(|| format!("--{key}"))?;
        }
    }
    let n = args.get("requests", 16usize);
    let interarrival = args.get("interarrival", 25u64);
    let slots = args.get("slots", 4usize).max(1);
    let seed = args.get("seed", 0u64);

    // mock geometry: same shape the hermetic engine tests use
    let prompt_len = 24usize;
    let max_seq = prompt_len + cfg.sampling.max_response;
    let (proto, reserve) = if cfg.mode.is_sparse() {
        let (budget, buffer) = (prompt_len + 8, 8);
        let b = MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer);
        (b, budget + buffer)
    } else {
        (MockModelBackend::dense(slots, prompt_len, max_seq, 32), max_seq)
    };
    let proto = proto.with_costs(CostModel::representative());
    let decode_lanes = if cfg.engine == EngineKind::Pipelined {
        cfg.rollout_workers.max(1)
    } else {
        1
    };
    let lanes = if cfg.engine == EngineKind::Pipelined && cfg.prefill.is_async() {
        decode_lanes + 1
    } else {
        decode_lanes
    };
    let backends: Vec<MockModelBackend> = (0..lanes).map(|_| proto.clone()).collect();
    let sched = Scheduler::worst_case(slots, reserve)
        .with_admission(cfg.memory.admission)
        .with_headroom(cfg.memory.kv_admit_headroom_pages)
        .with_order(cfg.admission_order)
        .with_sharing(cfg.memory.prefix_sharing);
    // like eval, the wall exists to drive admission, not to starve the
    // demo: clamp it up so every decode lane can fill its batch
    let page = cfg.memory.kv_page_tokens;
    let per_seq = sched.reserve_per_seq.div_ceil(page) * page;
    let wall = cfg.memory.global_kv_tokens.max(per_seq * slots * decode_lanes);
    let kv = KvMemoryManager::with_pages(wall, page);

    let tasks = benchmarks::training_split(n, prompt_len, seed);
    let trace = synthetic_trace(tasks, interarrival, cfg.serve.slo_ticks);
    let policy = RolloutPolicy::from_config(&cfg);
    let mut server = ServeServer::new(policy, cfg.engine, cfg.serve, backends, sched, kv);
    let report = server.run(&trace, seed)?;

    let (mut shed_deadline, mut shed_queue) = (0usize, 0usize);
    for o in &report.outcomes {
        if let ServeOutcome::Shed { reason, .. } = o {
            match reason {
                ShedReason::Deadline => shed_deadline += 1,
                ShedReason::QueueFull => shed_queue += 1,
            }
        }
    }
    println!(
        "serve: {} requests, interarrival {} ticks, engine {}, admission {}, slo {} ticks, queue-depth {}",
        trace.len(),
        interarrival,
        cfg.engine.label(),
        cfg.serve.admission.label(),
        cfg.serve.slo_ticks,
        cfg.serve.queue_depth,
    );
    println!(
        "completed {}  shed {} (deadline {}, queue-full {})  rounds {}  makespan {} ticks",
        report.completed(),
        report.shed(),
        shed_deadline,
        shed_queue,
        report.rounds,
        report.makespan_ticks,
    );
    for (name, h) in [
        ("ttft", &report.ttft),
        ("inter-token", &report.inter_token),
        ("e2e", &report.e2e),
    ] {
        println!(
            "{:<12} p50 {:>6}  p99 {:>6}  mean {:>8.1}  max {:>6}  ({} samples)",
            name,
            h.p50(),
            h.p99(),
            h.mean(),
            h.max(),
            h.len(),
        );
    }
    Ok(())
}

fn cmd_table3() -> Result<()> {
    println!("Table 3: benchmark statistics (synthetic analogs)\n");
    println!("{:<10} {:>5}  {:<6} {}", "Benchmark", "Size", "Ops", "Description");
    for b in benchmarks::suite() {
        println!(
            "{:<10} {:>5}  {:<6} {}",
            b.name,
            b.size,
            format!("{}-{}", b.ops_lo, b.ops_hi),
            b.description
        );
    }
    Ok(())
}

fn cmd_latency(args: &CliArgs) -> Result<()> {
    let engine = load_engine(args)?;
    let state = TrainState::new(engine.init_params(0)?);
    // touch the rollout path once so latencies are populated
    let mut cfg = ExperimentConfig::new(&engine.manifest.dir);
    cfg.apply_cli(args)?;
    let mode = RolloutMode::parse(&args.get("mode", "sparse-rl:rkv".to_string()))?;
    let tasks = benchmarks::training_split(
        engine.manifest.shapes.decode_batch,
        engine.manifest.config.prompt_len,
        0,
    );
    let ro = RolloutEngine::new(&engine, mode, cfg.sampling);
    let chunk: Vec<(usize, &sparse_rl::data::Task)> =
        tasks.iter().enumerate().map(|(i, t)| (i, t)).collect();
    ro.rollout_chunk(&state.params, &chunk, 0)?;
    println!("{:<20} {:>8} {:>12}", "artifact", "calls", "mean");
    for (name, calls, ns) in engine.latency_report() {
        println!(
            "{:<20} {:>8} {:>12}",
            name,
            calls,
            sparse_rl::util::bench::fmt_ns(ns)
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> CliArgs {
        CliArgs::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn eval_accepts_known_keys_and_extras() {
        let a = parse(
            "eval --model tiny --checkpoint c.srl --limit 10 --bench gsm \
             --engine continuous --replicas 2 --fault-retries 3 \
             --fault-policy quarantine --prefill-chunk-tokens 24 --seed 7",
        );
        assert!(reject_unknown_options(&a, EVAL_EXTRA_KEYS).is_ok());
    }

    #[test]
    fn eval_rejects_typod_flags_loudly() {
        // the classic silent misconfiguration: --replica (no s) used to be
        // dropped and the eval ran on 1 replica
        let a = parse("eval --model tiny --replica 4");
        let err = reject_unknown_options(&a, EVAL_EXTRA_KEYS)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--replica"), "got: {err}");
        assert!(err.contains("--replicas"), "must list the valid set: {err}");
        assert!(err.contains("--fault-policy"), "must list the valid set: {err}");
        // boolean-style flags are checked too
        let b = parse("eval --model tiny --vrebose");
        assert!(reject_unknown_options(&b, EVAL_EXTRA_KEYS).is_err());
    }

    #[test]
    fn serve_accepts_known_keys_and_extras() {
        let a = parse(
            "serve --requests 64 --interarrival 10 --slots 4 --seed 3 \
             --serve-admission slo --serve-queue-depth 8 --serve-slo-ticks 600 \
             --engine continuous --prefill-chunk-tokens 24",
        );
        assert!(reject_unknown_options(&a, SERVE_EXTRA_KEYS).is_ok());
    }

    #[test]
    fn serve_rejects_typod_flags_loudly() {
        let a = parse("serve --requests 64 --slo-tick 600");
        let err = reject_unknown_options(&a, SERVE_EXTRA_KEYS)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--slo-tick"), "got: {err}");
        assert!(err.contains("--serve-slo-ticks"), "must list the valid set: {err}");
        // eval-only extras are not serve extras
        let b = parse("serve --bench gsm");
        assert!(reject_unknown_options(&b, SERVE_EXTRA_KEYS).is_err());
    }
}
